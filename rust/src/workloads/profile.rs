//! Workload profiles: the bridge between *measured* execution-engine
//! statistics and the discrete-event simulator / analytic cost model.
//!
//! A profile describes a benchmark's data-flow ratios (measured on a real
//! sample run via [`crate::engine`]) scaled to a target input size, plus the
//! per-record CPU weights that position it on the paper's CPU-intensive ↔
//! IO-intensive spectrum (§6.3).

use crate::engine::DataStats;

/// Everything the simulator and cost model need to know about one job.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadProfile {
    pub name: String,
    /// Target (scaled) input size in bytes — the simulated job reads this
    /// much from HDFS even though the engine profiled a smaller sample.
    pub input_bytes: u64,
    /// Mean input record length (bytes).
    pub avg_input_record_bytes: f64,
    /// Map output bytes per input byte.
    pub map_selectivity_bytes: f64,
    /// Map output records per input record.
    pub map_selectivity_records: f64,
    /// Mean map-output record length (bytes).
    pub avg_map_record_bytes: f64,
    /// Combiner record survival ratio in (0,1]; 1.0 when no combiner.
    pub combiner_reduction: f64,
    pub has_combiner: bool,
    /// Reduce output bytes per shuffled byte.
    pub reduce_selectivity_bytes: f64,
    /// Max-partition / mean-partition ratio (≥ 1).
    pub partition_skew: f64,
    /// Measured zlib ratio of map output (compressed / raw).
    pub compress_ratio: f64,
    /// CPU cost per input record in the map function (ops; the cluster's
    /// `cpu_ops_per_sec` turns this into seconds).
    pub map_cpu_ops_per_record: f64,
    /// CPU cost per intermediate record in the reduce function (ops).
    pub reduce_cpu_ops_per_record: f64,
}

impl WorkloadProfile {
    /// Build a profile from engine-measured stats, scaled to `input_bytes`,
    /// with benchmark-specific CPU weights.
    pub fn from_stats(
        name: &str,
        stats: &DataStats,
        input_bytes: u64,
        has_combiner: bool,
        map_cpu_ops_per_record: f64,
        reduce_cpu_ops_per_record: f64,
    ) -> Self {
        let avg_in = if stats.input_records > 0 {
            stats.input_bytes as f64 / stats.input_records as f64
        } else {
            100.0
        };
        WorkloadProfile {
            name: name.to_string(),
            input_bytes,
            avg_input_record_bytes: avg_in.max(1.0),
            map_selectivity_bytes: stats.map_selectivity_bytes(),
            map_selectivity_records: stats.map_selectivity_records(),
            avg_map_record_bytes: stats.avg_map_record_bytes().max(1.0),
            combiner_reduction: if has_combiner { stats.combiner_reduction() } else { 1.0 },
            has_combiner,
            reduce_selectivity_bytes: stats.reduce_selectivity_bytes(),
            partition_skew: stats.partition_skew(),
            compress_ratio: stats.map_output_compress_ratio.clamp(0.01, 1.0),
            map_cpu_ops_per_record,
            reduce_cpu_ops_per_record,
        }
    }

    /// Total input records at the scaled size.
    pub fn input_records(&self) -> u64 {
        (self.input_bytes as f64 / self.avg_input_record_bytes).ceil() as u64
    }

    /// Total map-output bytes at the scaled size.
    pub fn map_output_bytes(&self) -> u64 {
        (self.input_bytes as f64 * self.map_selectivity_bytes).ceil() as u64
    }

    /// Total map-output records at the scaled size.
    pub fn map_output_records(&self) -> u64 {
        (self.input_records() as f64 * self.map_selectivity_records).ceil() as u64
    }

    /// Bytes shuffled to reducers (post-combiner, pre-compression).
    pub fn shuffle_bytes(&self) -> u64 {
        (self.map_output_bytes() as f64 * self.combiner_reduction).ceil() as u64
    }

    /// A copy of this profile as a *single-shot measurement* would see it:
    /// every data-flow ratio and CPU weight picks up independent lognormal
    /// error of the given sigma. Profiling-based tuners (Starfish, PPABS)
    /// consume this — they characterize a job from one instrumented run,
    /// whereas SPSA averages information across many live observations
    /// (the paper's §6.8 point 4).
    pub fn with_measurement_noise(&self, rng: &mut crate::util::rng::Rng, sigma: f64) -> Self {
        let mut p = self.clone();
        let mut jitter = |x: &mut f64| {
            *x *= rng.lognormal_unit_mean(sigma);
        };
        jitter(&mut p.avg_input_record_bytes);
        jitter(&mut p.map_selectivity_bytes);
        jitter(&mut p.map_selectivity_records);
        jitter(&mut p.avg_map_record_bytes);
        jitter(&mut p.reduce_selectivity_bytes);
        jitter(&mut p.map_cpu_ops_per_record);
        jitter(&mut p.reduce_cpu_ops_per_record);
        p.combiner_reduction = (p.combiner_reduction * rng.lognormal_unit_mean(sigma)).clamp(0.01, 1.0);
        p.compress_ratio = (p.compress_ratio * rng.lognormal_unit_mean(sigma)).clamp(0.01, 1.0);
        p.partition_skew = (p.partition_skew * rng.lognormal_unit_mean(sigma)).max(1.0);
        p
    }

    /// The feature vector consumed by the AOT cost-model artifact. Order
    /// must match `python/compile/model.py::WORKLOAD_FEATURES`.
    pub fn to_features(&self) -> Vec<f32> {
        vec![
            self.input_bytes as f32,
            self.avg_input_record_bytes as f32,
            self.map_selectivity_bytes as f32,
            self.map_selectivity_records as f32,
            self.avg_map_record_bytes as f32,
            self.combiner_reduction as f32,
            self.reduce_selectivity_bytes as f32,
            self.partition_skew as f32,
            self.compress_ratio as f32,
            self.map_cpu_ops_per_record as f32,
            self.reduce_cpu_ops_per_record as f32,
        ]
    }
}

/// Number of workload features in [`WorkloadProfile::to_features`].
pub const N_WORKLOAD_FEATURES: usize = 11;

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> DataStats {
        DataStats {
            input_bytes: 1_000,
            input_records: 10,
            map_output_records: 100,
            map_output_bytes: 2_000,
            combine_output_records: 50,
            combine_output_bytes: 1_000,
            distinct_keys: 40,
            partition_bytes: vec![600, 400],
            reduce_output_records: 40,
            reduce_output_bytes: 500,
            map_output_compress_ratio: 0.4,
        }
    }

    #[test]
    fn scaling_preserves_ratios() {
        let p = WorkloadProfile::from_stats("t", &stats(), 1 << 30, true, 100.0, 50.0);
        assert!((p.map_selectivity_bytes - 2.0).abs() < 1e-12);
        assert_eq!(p.map_output_bytes(), 2 << 30);
        let recs = p.input_records();
        // avg record 100 B ⇒ ceil(2^30 / 100)
        assert_eq!(recs, ((1u64 << 30) as f64 / 100.0).ceil() as u64);
        assert_eq!(p.map_output_records(), recs * 10);
    }

    #[test]
    fn combiner_halves_shuffle() {
        let p = WorkloadProfile::from_stats("t", &stats(), 1 << 20, true, 1.0, 1.0);
        assert!((p.combiner_reduction - 0.5).abs() < 1e-12);
        assert_eq!(p.shuffle_bytes(), p.map_output_bytes() / 2);
    }

    #[test]
    fn no_combiner_means_unit_reduction() {
        let p = WorkloadProfile::from_stats("t", &stats(), 1 << 20, false, 1.0, 1.0);
        assert!((p.combiner_reduction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn feature_vector_length() {
        let p = WorkloadProfile::from_stats("t", &stats(), 1 << 20, true, 1.0, 1.0);
        assert_eq!(p.to_features().len(), N_WORKLOAD_FEATURES);
    }
}
