//! Synthetic dataset generators — stand-ins for the paper's Wikipedia/PUMA
//! corpora and TeraGen output (DESIGN.md §1 substitution table).
//!
//! Text is generated with a Zipf(≈1.1) word-rank distribution over a
//! synthetic vocabulary, reproducing the key-frequency skew that drives
//! combiner effectiveness and partition imbalance in the text benchmarks.

use crate::util::rng::Rng;

/// Deterministic pseudo-word for a vocabulary rank: letters derived from the
/// rank so the vocabulary is unbounded and stable across runs.
pub fn word_for_rank(rank: u64) -> String {
    // base-20 consonant-vowel syllables → pronounceable-ish unique words
    const C: &[u8] = b"bcdfghjklmnpqrstvwxz";
    const V: &[u8] = b"aeiou";
    let mut w = String::new();
    let mut r = rank;
    loop {
        w.push(C[(r % 20) as usize] as char);
        w.push(V[((r / 20) % 5) as usize] as char);
        r /= 100;
        if r == 0 {
            break;
        }
    }
    w
}

/// Configuration for the synthetic text corpus.
#[derive(Clone, Debug)]
pub struct TextCorpusSpec {
    /// Vocabulary size (distinct words).
    pub vocab: u64,
    /// Zipf exponent (natural language ≈ 1.0–1.2).
    pub zipf_s: f64,
    /// Words per line (sentence length), sampled uniform ±50 %.
    pub words_per_line: u64,
}

impl Default for TextCorpusSpec {
    fn default() -> Self {
        TextCorpusSpec { vocab: 50_000, zipf_s: 1.1, words_per_line: 12 }
    }
}

/// Generate approximately `bytes` of newline-delimited Zipf text.
pub fn generate_text(spec: &TextCorpusSpec, bytes: u64, rng: &mut Rng) -> Vec<u8> {
    let mut out = Vec::with_capacity(bytes as usize + 64);
    while (out.len() as u64) < bytes {
        let n_words = rng.range_u64(spec.words_per_line / 2 + 1, spec.words_per_line * 3 / 2 + 1);
        for i in 0..n_words {
            if i > 0 {
                out.push(b' ');
            }
            let rank = rng.zipf(spec.vocab, spec.zipf_s);
            out.extend_from_slice(word_for_rank(rank).as_bytes());
        }
        out.push(b'\n');
    }
    out.truncate(bytes as usize);
    // keep the data line-clean: drop a possibly cut final line
    if let Some(pos) = out.iter().rposition(|&b| b == b'\n') {
        out.truncate(pos + 1);
    }
    out
}

/// Generate documents for the Inverted-Index benchmark: each line is
/// `docNNNN<TAB>text...` (the mapper needs a document id per record).
pub fn generate_documents(spec: &TextCorpusSpec, bytes: u64, rng: &mut Rng) -> Vec<u8> {
    let mut out = Vec::with_capacity(bytes as usize + 64);
    let mut doc = 0u64;
    while (out.len() as u64) < bytes {
        out.extend_from_slice(format!("doc{doc:06}\t").as_bytes());
        let n_words = rng.range_u64(spec.words_per_line, spec.words_per_line * 4);
        for i in 0..n_words {
            if i > 0 {
                out.push(b' ');
            }
            let rank = rng.zipf(spec.vocab, spec.zipf_s);
            out.extend_from_slice(word_for_rank(rank).as_bytes());
        }
        out.push(b'\n');
        doc += 1;
    }
    out.truncate(bytes as usize);
    if let Some(pos) = out.iter().rposition(|&b| b == b'\n') {
        out.truncate(pos + 1);
    }
    out
}

/// TeraGen record length: 10-byte key + 90-byte payload (TeraSort format).
pub const TERA_RECORD_LEN: usize = 100;

/// Generate `n_records` TeraGen-format records (10-byte random binary key,
/// 90-byte structured payload).
pub fn generate_tera(n_records: u64, rng: &mut Rng) -> Vec<u8> {
    let mut out = Vec::with_capacity((n_records as usize) * TERA_RECORD_LEN);
    for i in 0..n_records {
        // 10-byte key
        for _ in 0..10 {
            out.push(rng.next_u64() as u8);
        }
        // 90-byte payload: row id + filler (mirrors teragen's layout)
        let row = format!("{i:032x}");
        out.extend_from_slice(row.as_bytes());
        let filler = [b'A' + (i % 26) as u8; 58];
        out.extend_from_slice(&filler);
    }
    debug_assert_eq!(out.len(), n_records as usize * TERA_RECORD_LEN);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn words_unique_per_rank() {
        let mut seen = std::collections::BTreeSet::new();
        for r in 1..2000 {
            assert!(seen.insert(word_for_rank(r)), "dup word at rank {r}");
        }
    }

    #[test]
    fn text_size_and_lines() {
        let mut rng = Rng::seeded(1);
        let data = generate_text(&TextCorpusSpec::default(), 10_000, &mut rng);
        assert!(data.len() <= 10_000);
        assert!(data.len() > 8_000);
        assert_eq!(*data.last().unwrap(), b'\n');
        let lines = data.split(|&b| b == b'\n').filter(|l| !l.is_empty()).count();
        assert!(lines > 50);
    }

    #[test]
    fn text_is_zipf_skewed() {
        let mut rng = Rng::seeded(2);
        let data = generate_text(&TextCorpusSpec::default(), 200_000, &mut rng);
        let text = String::from_utf8(data).unwrap();
        let mut counts: BTreeMap<&str, u64> = BTreeMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w).or_default() += 1;
        }
        let mut freqs: Vec<u64> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        // top word should dwarf the median word
        let top = freqs[0];
        let median = freqs[freqs.len() / 2];
        assert!(top > 20 * median.max(1), "top {top} median {median}");
    }

    #[test]
    fn documents_have_ids() {
        let mut rng = Rng::seeded(3);
        let data = generate_documents(&TextCorpusSpec::default(), 20_000, &mut rng);
        for line in data.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let s = String::from_utf8_lossy(line);
            assert!(s.starts_with("doc"), "line {s}");
            assert!(s.contains('\t'));
        }
    }

    #[test]
    fn teragen_format() {
        let mut rng = Rng::seeded(4);
        let data = generate_tera(100, &mut rng);
        assert_eq!(data.len(), 100 * TERA_RECORD_LEN);
    }

    #[test]
    fn teragen_keys_spread() {
        let mut rng = Rng::seeded(5);
        let data = generate_tera(1000, &mut rng);
        // first key byte should span the byte range decently
        let mut lo = 0u32;
        let mut hi = 0u32;
        for i in 0..1000 {
            let b = data[i * TERA_RECORD_LEN];
            if b < 0x40 {
                lo += 1;
            }
            if b >= 0xC0 {
                hi += 1;
            }
        }
        assert!(lo > 150 && hi > 150, "lo {lo} hi {hi}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_text(&TextCorpusSpec::default(), 5000, &mut Rng::seeded(9));
        let b = generate_text(&TextCorpusSpec::default(), 5000, &mut Rng::seeded(9));
        assert_eq!(a, b);
    }
}
