//! Cluster topology: the 25-node testbed of the paper's §6.2, generalized.
//!
//! One node acts as NameNode / ResourceManager (the paper runs the SPSA
//! process there too); the rest are DataNodes with fixed map/reduce slots
//! (v1) or an equivalent container capacity (v2 — the paper sets 3 map + 2
//! reduce slots per node for both, which we mirror).

/// Static description of one worker node.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    /// CPU throughput per core, in "record-cost units" per second. The
    /// workload descriptors express map/reduce CPU cost in the same units,
    /// so this calibrates absolute simulated times.
    pub cpu_ops_per_sec: f64,
    /// Cores available to tasks.
    pub cores: u32,
    /// Sequential disk bandwidth in bytes/s (shared by all tasks on the node).
    pub disk_bw: f64,
    /// NIC bandwidth in bytes/s (full duplex, shared).
    pub net_bw: f64,
    /// Memory per node in bytes.
    pub memory: u64,
}

impl Default for NodeSpec {
    fn default() -> Self {
        // Paper §6.2: 8-core Xeon E3 2.5 GHz, 16 GB RAM, HDD, 1 GbE.
        NodeSpec {
            cpu_ops_per_sec: 2.0e8,
            cores: 8,
            disk_bw: 120.0e6,  // ~120 MB/s HDD sequential
            net_bw: 117.0e6,   // ~1 GbE effective
            memory: 16 << 30,
        }
    }
}

/// Whole-cluster specification.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Total nodes including the master.
    pub nodes: u32,
    /// Map slots per worker node (paper: 3).
    pub map_slots_per_node: u32,
    /// Reduce slots per worker node (paper: 2).
    pub reduce_slots_per_node: u32,
    /// Hardware of a stock worker.
    pub node: NodeSpec,
    /// Per-worker hardware overrides — heterogeneous fleets mixing machine
    /// generations. `(worker index, spec)`; workers not listed run `node`.
    pub overrides: Vec<(u32, NodeSpec)>,
}

impl ClusterSpec {
    /// The paper's 25-node cluster (§6.2).
    pub fn paper_cluster() -> Self {
        ClusterSpec {
            nodes: 25,
            map_slots_per_node: 3,
            reduce_slots_per_node: 2,
            node: NodeSpec::default(),
            overrides: Vec::new(),
        }
    }

    /// A reduced cluster for fast unit tests.
    pub fn tiny() -> Self {
        ClusterSpec {
            nodes: 3,
            map_slots_per_node: 2,
            reduce_slots_per_node: 1,
            node: NodeSpec::default(),
            overrides: Vec::new(),
        }
    }

    /// Builder: give one worker different hardware (later wins on repeats).
    pub fn with_node_override(mut self, worker: u32, spec: NodeSpec) -> Self {
        self.overrides.push((worker, spec));
        self
    }

    /// The hardware of one worker: its override if present, else the stock
    /// `node` spec.
    pub fn node_spec(&self, worker: u32) -> &NodeSpec {
        self.overrides
            .iter()
            .rev()
            .find(|(w, _)| *w == worker)
            .map(|(_, s)| s)
            .unwrap_or(&self.node)
    }

    /// Worker (DataNode) count: one node is the dedicated master.
    pub fn workers(&self) -> u32 {
        self.nodes.saturating_sub(1).max(1)
    }

    /// Cluster-wide map slots: paper §6.2 — 24 × 3 = 72.
    pub fn total_map_slots(&self) -> u32 {
        self.workers() * self.map_slots_per_node
    }

    /// Cluster-wide reduce slots: paper §6.2 — 24 × 2 = 48.
    pub fn total_reduce_slots(&self) -> u32 {
        self.workers() * self.reduce_slots_per_node
    }

    /// The paper's partial-workload sizing rule (§6.4): twice the number of
    /// map slots times the HDFS block size ⇒ exactly two waves of maps.
    pub fn partial_workload_bytes(&self, dfs_block_size: u64) -> u64 {
        2 * self.total_map_slots() as u64 * dfs_block_size
    }

    /// Cross-rack aggregate network bisection (bytes/s). Single-switch
    /// fabric: bounded by the sum of NIC bandwidths on either side.
    pub fn bisection_bw(&self) -> f64 {
        (0..self.workers()).map(|w| self.node_spec(w).net_bw).sum::<f64>() / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_slot_math() {
        let c = ClusterSpec::paper_cluster();
        assert_eq!(c.workers(), 24);
        assert_eq!(c.total_map_slots(), 72);
        assert_eq!(c.total_reduce_slots(), 48);
    }

    #[test]
    fn partial_workload_is_two_waves() {
        let c = ClusterSpec::paper_cluster();
        let block = 128u64 << 20;
        let bytes = c.partial_workload_bytes(block);
        assert_eq!(bytes, 2 * 72 * block);
        // Two waves: splits == 2 × slots
        assert_eq!(bytes / block, 144);
    }

    #[test]
    fn tiny_cluster_nonzero() {
        let c = ClusterSpec::tiny();
        assert!(c.total_map_slots() > 0);
        assert!(c.total_reduce_slots() > 0);
        assert!(c.bisection_bw() > 0.0);
    }

    #[test]
    fn node_overrides_make_heterogeneous_fleet() {
        let slow = NodeSpec { cpu_ops_per_sec: 1.0e8, disk_bw: 60.0e6, ..NodeSpec::default() };
        let c = ClusterSpec::paper_cluster().with_node_override(3, slow.clone());
        assert_eq!(c.node_spec(3).cpu_ops_per_sec, 1.0e8);
        assert_eq!(c.node_spec(2).cpu_ops_per_sec, NodeSpec::default().cpu_ops_per_sec);
        // a second override of the same worker wins
        let faster = NodeSpec { cpu_ops_per_sec: 4.0e8, ..NodeSpec::default() };
        let c = c.with_node_override(3, faster);
        assert_eq!(c.node_spec(3).cpu_ops_per_sec, 4.0e8);
    }

    #[test]
    fn bisection_bw_counts_per_node_nics() {
        let half_nic = NodeSpec { net_bw: NodeSpec::default().net_bw / 2.0, ..NodeSpec::default() };
        let homo = ClusterSpec::paper_cluster();
        let hetero = ClusterSpec::paper_cluster().with_node_override(0, half_nic);
        assert!(hetero.bisection_bw() < homo.bisection_bw());
    }
}
