//! HDFS model: files are split into blocks, blocks are placed on DataNodes
//! with replication, and the scheduler asks for the locality of a split
//! (node-local / rack-local / remote) — which decides whether a map task
//! reads from local disk or across the network.

use crate::util::rng::Rng;

/// One HDFS block with its replica placement.
#[derive(Clone, Debug)]
pub struct Block {
    pub id: u64,
    pub size: u64,
    /// Worker indices holding a replica.
    pub replicas: Vec<u32>,
}

/// A file laid out on the simulated HDFS.
#[derive(Clone, Debug)]
pub struct HdfsFile {
    pub name: String,
    pub blocks: Vec<Block>,
}

impl HdfsFile {
    pub fn total_bytes(&self) -> u64 {
        self.blocks.iter().map(|b| b.size).sum()
    }
}

/// NameNode-equivalent: block placement and lookup.
#[derive(Clone, Debug)]
pub struct Namenode {
    workers: u32,
    replication: u32,
    next_block: u64,
}

impl Namenode {
    pub fn new(workers: u32, replication: u32) -> Self {
        assert!(workers >= 1);
        Namenode { workers, replication: replication.clamp(1, workers), next_block: 0 }
    }

    /// Write a file of `bytes` split by `block_size`, choosing replica sets
    /// round-robin with a random rotation (mirrors HDFS's pipeline
    /// placement well enough for locality statistics).
    pub fn create_file(&mut self, name: &str, bytes: u64, block_size: u64, rng: &mut Rng) -> HdfsFile {
        assert!(block_size > 0);
        let mut blocks = Vec::new();
        let mut remaining = bytes;
        while remaining > 0 {
            let size = remaining.min(block_size);
            let primary = rng.below(self.workers as u64) as u32;
            let mut replicas = Vec::with_capacity(self.replication as usize);
            for r in 0..self.replication {
                replicas.push((primary + r) % self.workers);
            }
            blocks.push(Block { id: self.next_block, size, replicas });
            self.next_block += 1;
            remaining -= size;
        }
        HdfsFile { name: name.to_string(), blocks }
    }

    /// Is any replica of `block` on `worker`?
    pub fn is_local(&self, block: &Block, worker: u32) -> bool {
        block.replicas.contains(&worker)
    }

    /// Fraction of a file's blocks that have a replica on the given worker —
    /// the expected data-local hit rate if all its splits ran there.
    pub fn locality_fraction(&self, file: &HdfsFile, worker: u32) -> f64 {
        if file.blocks.is_empty() {
            return 0.0;
        }
        let hits = file.blocks.iter().filter(|b| self.is_local(b, worker)).count();
        hits as f64 / file.blocks.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_file_into_blocks() {
        let mut nn = Namenode::new(24, 2);
        let mut rng = Rng::seeded(1);
        let f = nn.create_file("input", 300 << 20, 128 << 20, &mut rng);
        assert_eq!(f.blocks.len(), 3);
        assert_eq!(f.blocks[0].size, 128 << 20);
        assert_eq!(f.blocks[2].size, 44 << 20);
        assert_eq!(f.total_bytes(), 300 << 20);
    }

    #[test]
    fn replication_respected() {
        let mut nn = Namenode::new(24, 2);
        let mut rng = Rng::seeded(2);
        let f = nn.create_file("x", 1 << 30, 128 << 20, &mut rng);
        for b in &f.blocks {
            assert_eq!(b.replicas.len(), 2);
            assert_ne!(b.replicas[0], b.replicas[1]);
            assert!(b.replicas.iter().all(|&w| w < 24));
        }
    }

    #[test]
    fn replication_clamped_to_workers() {
        let nn = Namenode::new(2, 5);
        assert_eq!(nn.replication, 2);
    }

    #[test]
    fn locality_fraction_sane() {
        let mut nn = Namenode::new(10, 2);
        let mut rng = Rng::seeded(3);
        let f = nn.create_file("y", 100 * (128 << 20), 128 << 20, &mut rng);
        // With 100 blocks × 2 replicas over 10 workers, each worker holds
        // ~20% of blocks.
        let frac = nn.locality_fraction(&f, 0);
        assert!(frac > 0.05 && frac < 0.45, "frac {frac}");
    }

    #[test]
    fn block_ids_unique_across_files() {
        let mut nn = Namenode::new(4, 1);
        let mut rng = Rng::seeded(4);
        let a = nn.create_file("a", 256 << 20, 128 << 20, &mut rng);
        let b = nn.create_file("b", 256 << 20, 128 << 20, &mut rng);
        let mut ids: Vec<u64> =
            a.blocks.iter().chain(b.blocks.iter()).map(|x| x.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4);
    }
}
