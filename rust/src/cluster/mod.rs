//! Simulated cluster substrate: topology (the paper's 25-node testbed),
//! HDFS block placement, and shared-resource contention.

pub mod hdfs;
pub mod resources;
pub mod topology;

pub use hdfs::{Block, HdfsFile, Namenode};
pub use resources::{transfer_time, Resource, ResourceTracker};
pub use topology::{ClusterSpec, NodeSpec};
