//! Shared-resource contention model.
//!
//! Disk and NIC bandwidth on a node are shared by every concurrently-running
//! task on it. The simulator uses a quasi-static processor-sharing
//! approximation: a task's IO phase is priced at `bw / users` with `users`
//! sampled when the phase starts. This captures the first-order effect the
//! paper's knobs interact with (e.g. more reducers per node ⇒ slower
//! per-reducer shuffle) without a full fluid-flow solver.

use super::topology::ClusterSpec;

/// Resource classes a task phase can occupy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resource {
    Disk,
    Net,
    Cpu,
}

/// Tracks per-node active users of each resource class.
#[derive(Clone, Debug)]
pub struct ResourceTracker {
    disk_users: Vec<u32>,
    net_users: Vec<u32>,
    cpu_users: Vec<u32>,
    spec: ClusterSpec,
}

impl ResourceTracker {
    pub fn new(spec: &ClusterSpec) -> Self {
        let n = spec.workers() as usize;
        ResourceTracker {
            disk_users: vec![0; n],
            net_users: vec![0; n],
            cpu_users: vec![0; n],
            spec: spec.clone(),
        }
    }

    fn slot(&mut self, r: Resource) -> &mut Vec<u32> {
        match r {
            Resource::Disk => &mut self.disk_users,
            Resource::Net => &mut self.net_users,
            Resource::Cpu => &mut self.cpu_users,
        }
    }

    pub fn acquire(&mut self, node: u32, r: Resource) {
        let v = self.slot(r);
        v[node as usize] += 1;
    }

    pub fn release(&mut self, node: u32, r: Resource) {
        let v = self.slot(r);
        debug_assert!(v[node as usize] > 0, "release without acquire");
        v[node as usize] = v[node as usize].saturating_sub(1);
    }

    pub fn users(&self, node: u32, r: Resource) -> u32 {
        match r {
            Resource::Disk => self.disk_users[node as usize],
            Resource::Net => self.net_users[node as usize],
            Resource::Cpu => self.cpu_users[node as usize],
        }
    }

    /// Effective disk bandwidth for one task on `node`, *including* itself
    /// as a user (call after `acquire`). Reads the node's own hardware spec,
    /// so heterogeneous fleets price IO per machine.
    pub fn disk_bw(&self, node: u32) -> f64 {
        let users = self.disk_users[node as usize].max(1) as f64;
        self.spec.node_spec(node).disk_bw / users
    }

    /// Effective NIC bandwidth for one task on `node`.
    pub fn net_bw(&self, node: u32) -> f64 {
        let users = self.net_users[node as usize].max(1) as f64;
        self.spec.node_spec(node).net_bw / users
    }

    /// Effective CPU rate for one task on `node` — cores are dedicated up to
    /// the core count, then shared.
    pub fn cpu_rate(&self, node: u32) -> f64 {
        let users = self.cpu_users[node as usize].max(1) as f64;
        let spec = self.spec.node_spec(node);
        let cores = spec.cores as f64;
        if users <= cores {
            spec.cpu_ops_per_sec
        } else {
            spec.cpu_ops_per_sec * cores / users
        }
    }
}

/// RAII-free scoped helper: compute a transfer duration under current
/// contention.
pub fn transfer_time(bytes: u64, bw: f64) -> f64 {
    if bw <= 0.0 {
        return f64::INFINITY;
    }
    bytes as f64 / bw
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracker() -> ResourceTracker {
        ResourceTracker::new(&ClusterSpec::tiny())
    }

    #[test]
    fn bandwidth_divides_by_users() {
        let mut t = tracker();
        t.acquire(0, Resource::Disk);
        let solo = t.disk_bw(0);
        t.acquire(0, Resource::Disk);
        t.acquire(0, Resource::Disk);
        let shared = t.disk_bw(0);
        assert!((solo / shared - 3.0).abs() < 1e-9);
    }

    #[test]
    fn release_restores() {
        let mut t = tracker();
        t.acquire(1, Resource::Net);
        t.acquire(1, Resource::Net);
        t.release(1, Resource::Net);
        assert_eq!(t.users(1, Resource::Net), 1);
    }

    #[test]
    fn cpu_free_until_core_count() {
        let mut t = tracker();
        let full = t.cpu_rate(0);
        for _ in 0..8 {
            t.acquire(0, Resource::Cpu);
        }
        assert_eq!(t.cpu_rate(0), full); // 8 users on 8 cores
        t.acquire(0, Resource::Cpu);
        assert!(t.cpu_rate(0) < full); // 9th shares
    }

    #[test]
    fn nodes_are_independent() {
        let mut t = tracker();
        t.acquire(0, Resource::Disk);
        t.acquire(0, Resource::Disk);
        t.acquire(1, Resource::Disk);
        assert!(t.disk_bw(1) > t.disk_bw(0));
    }

    #[test]
    fn transfer_time_math() {
        assert!((transfer_time(100, 50.0) - 2.0).abs() < 1e-12);
        assert!(transfer_time(1, 0.0).is_infinite());
    }

    #[test]
    fn heterogeneous_node_rates_follow_overrides() {
        use crate::cluster::NodeSpec;
        let slow = NodeSpec {
            disk_bw: 30e6,
            net_bw: 20e6,
            cpu_ops_per_sec: 1e8,
            ..NodeSpec::default()
        };
        let spec = ClusterSpec::tiny().with_node_override(1, slow);
        let t = ResourceTracker::new(&spec);
        assert!(t.disk_bw(1) < t.disk_bw(0));
        assert!(t.net_bw(1) < t.net_bw(0));
        assert!(t.cpu_rate(1) < t.cpu_rate(0));
        assert_eq!(t.disk_bw(1), 30e6);
    }
}
