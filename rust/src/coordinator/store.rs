//! Cross-campaign observation store: the broker's per-trial memo cache
//! promoted to a service-scoped tier (Tuneful's amortization claim —
//! production tuners pay for an observation once *across* jobs and
//! users, not once per trial).
//!
//! Keying is `(benchmark, workload version, scenario signature,
//! store-quantized θ)`. The θ quantum is deliberately **coarse**
//! (default 0.02 per coordinate vs the broker memo's 1e-6) so revisits
//! from different seeds and different tuners land in the same cell —
//! which is exactly why a served value is *noise-frozen*: it was
//! observed at a nearby θ under a different noise stream, and consumers
//! must flag it ([`ObsSource::Store`]) rather than present it as a fresh
//! measurement.
//!
//! Determinism contract (enforced by `repro lint` and the service replay
//! gate): `BTreeMap` keys only — iteration order is the key order, never
//! a hash seed's; eviction is by a **logical insertion tick**, never
//! wall-clock.
//!
//! [`ObsSource::Store`]: crate::tuner::ObsSource

use std::collections::BTreeMap;

use crate::config::HadoopVersion;
use crate::sim::ScenarioSpec;
use crate::workloads::Benchmark;

/// Default per-coordinate θ-cell size. Coarser than any tuner's step so
/// cross-seed/cross-tuner revisits of "the same" configuration hit.
pub const DEFAULT_STORE_QUANT: f64 = 0.02;

/// Default capacity (entries) before FIFO eviction kicks in.
pub const DEFAULT_STORE_CAPACITY: usize = 65_536;

/// Deterministic signature of a [`ScenarioSpec`]: FNV-1a over the exact
/// bit patterns of its fields, in declaration order. Two specs collide
/// iff they describe bit-identical fault schedules — no hash seeds, no
/// float rounding.
pub fn scenario_sig(s: &ScenarioSpec) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    fn mix(h: u64, x: u64) -> u64 {
        (h ^ x).wrapping_mul(PRIME)
    }
    let mut h = OFFSET;
    h = mix(h, s.task_failure_p.to_bits());
    h = mix(h, s.max_attempts);
    for c in &s.node_crashes {
        h = mix(h, c.at_s.to_bits());
        h = mix(h, u64::from(c.node));
    }
    for n in &s.slow_nodes {
        h = mix(h, u64::from(n.node));
        h = mix(h, n.speed.to_bits());
    }
    h = mix(h, u64::from(s.speculative_maps));
    h = mix(h, u64::from(s.speculative_reduces));
    h
}

pub(crate) fn version_tag(v: HadoopVersion) -> u8 {
    match v {
        HadoopVersion::V1 => 1,
        HadoopVersion::V2 => 2,
    }
}

/// Full store key: workload identity + scenario + θ cell. `Ord` derives
/// from field order, so a `(benchmark, version, scenario)` prefix scan
/// is one `range` walk.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct StoreKey {
    pub benchmark: Benchmark,
    pub version_tag: u8,
    pub scenario_sig: u64,
    /// Per-coordinate `round(θ_i / quant)` cell of the observed θ.
    pub cell: Vec<i64>,
}

/// One stored observation: the *first* value ever observed in its cell
/// (first-write-wins, like the broker memo — replays are stable).
#[derive(Clone, Debug)]
pub struct StoredObs {
    /// Exact θ as observed (full-dimensional, not the cell center).
    pub theta: Vec<f64>,
    /// Observed f, frozen at its original noise draw.
    pub f: f64,
    /// Ordinal of the campaign/request that produced it.
    pub campaign: u64,
    /// Logical insertion tick — the deterministic eviction order.
    tick: u64,
}

/// The campaign-/service-scoped observation store.
pub struct ObservationStore {
    quant: f64,
    capacity: usize,
    map: BTreeMap<StoreKey, StoredObs>,
    /// tick → key, for O(log n) oldest-first eviction.
    order: BTreeMap<u64, StoreKey>,
    tick: u64,
    inserts: u64,
    hits: u64,
    evictions: u64,
}

impl Default for ObservationStore {
    fn default() -> Self {
        Self::new()
    }
}

impl ObservationStore {
    pub fn new() -> Self {
        ObservationStore {
            quant: DEFAULT_STORE_QUANT,
            capacity: DEFAULT_STORE_CAPACITY,
            map: BTreeMap::new(),
            order: BTreeMap::new(),
            tick: 0,
            inserts: 0,
            hits: 0,
            evictions: 0,
        }
    }

    pub fn with_quant(mut self, quant: f64) -> Self {
        assert!(quant > 0.0, "store quantization step must be positive");
        self.quant = quant;
        self
    }

    pub fn with_capacity(mut self, capacity: usize) -> Self {
        assert!(capacity > 0, "store capacity must be positive");
        self.capacity = capacity;
        self
    }

    /// The per-coordinate θ-cell size.
    pub fn quant(&self) -> f64 {
        self.quant
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Lifetime counters: (inserts accepted, lookup hits, evictions).
    pub fn counters(&self) -> (u64, u64, u64) {
        (self.inserts, self.hits, self.evictions)
    }

    fn cell(&self, theta: &[f64]) -> Vec<i64> {
        theta.iter().map(|t| (t / self.quant).round() as i64).collect()
    }

    fn key(
        &self,
        benchmark: Benchmark,
        version: HadoopVersion,
        scenario: &ScenarioSpec,
        theta: &[f64],
    ) -> StoreKey {
        StoreKey {
            benchmark,
            version_tag: version_tag(version),
            scenario_sig: scenario_sig(scenario),
            cell: self.cell(theta),
        }
    }

    /// Record one observation. First write per cell wins; a revisit of an
    /// occupied cell is a no-op (the stored value is what replays —
    /// re-observing under another noise stream must not perturb earlier
    /// consumers' results). Evicts oldest-tick entries past capacity.
    pub fn insert(
        &mut self,
        benchmark: Benchmark,
        version: HadoopVersion,
        scenario: &ScenarioSpec,
        theta: &[f64],
        f: f64,
        campaign: u64,
    ) {
        if f.is_nan() {
            return; // a NaN can never serve as a replayable observation
        }
        let key = self.key(benchmark, version, scenario, theta);
        if self.map.contains_key(&key) {
            return;
        }
        let tick = self.tick;
        self.tick += 1;
        self.order.insert(tick, key.clone());
        self.map.insert(key, StoredObs { theta: theta.to_vec(), f, campaign, tick });
        self.inserts += 1;
        while self.map.len() > self.capacity {
            // oldest logical tick goes first — wall-clock never enters
            let oldest = self.order.keys().next().copied();
            if let Some(t) = oldest {
                if let Some(k) = self.order.remove(&t) {
                    self.map.remove(&k);
                    self.evictions += 1;
                }
            }
        }
    }

    /// Serve the stored observation for `theta`'s cell, if any.
    pub fn lookup(
        &mut self,
        benchmark: Benchmark,
        version: HadoopVersion,
        scenario: &ScenarioSpec,
        theta: &[f64],
    ) -> Option<&StoredObs> {
        let key = self.key(benchmark, version, scenario, theta);
        let hit = self.map.get(&key);
        if hit.is_some() {
            self.hits += 1;
        }
        hit
    }

    /// All records for one `(benchmark, version, scenario)` prefix, in
    /// cell order — the deterministic record set warm-start and pruning
    /// work from.
    pub fn records_for(
        &self,
        benchmark: Benchmark,
        version: HadoopVersion,
        scenario: &ScenarioSpec,
    ) -> Vec<&StoredObs> {
        let lo = StoreKey {
            benchmark,
            version_tag: version_tag(version),
            scenario_sig: scenario_sig(scenario),
            cell: Vec::new(), // empty sorts before every non-empty cell
        };
        self.map
            .range(lo.clone()..)
            .take_while(|(k, _)| {
                k.benchmark == lo.benchmark
                    && k.version_tag == lo.version_tag
                    && k.scenario_sig == lo.scenario_sig
            })
            .map(|(_, v)| v)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn benign() -> ScenarioSpec {
        ScenarioSpec::default()
    }

    #[test]
    fn first_write_wins_and_lookup_is_cell_based() {
        let mut s = ObservationStore::new().with_quant(0.1);
        s.insert(Benchmark::Terasort, HadoopVersion::V1, &benign(), &[0.33, 0.7], 10.0, 0);
        // same cell, different exact θ and different value: no-op
        s.insert(Benchmark::Terasort, HadoopVersion::V1, &benign(), &[0.31, 0.71], 99.0, 1);
        assert_eq!(s.len(), 1);
        let hit = s
            .lookup(Benchmark::Terasort, HadoopVersion::V1, &benign(), &[0.29, 0.69])
            .expect("same cell");
        assert_eq!(hit.f, 10.0);
        assert_eq!(hit.campaign, 0);
        // different benchmark → different key space
        assert!(s
            .lookup(Benchmark::Grep, HadoopVersion::V1, &benign(), &[0.33, 0.7])
            .is_none());
        assert_eq!(s.counters(), (1, 1, 0));
    }

    #[test]
    fn nan_observations_are_rejected() {
        let mut s = ObservationStore::new();
        s.insert(Benchmark::Grep, HadoopVersion::V1, &benign(), &[0.5], f64::NAN, 0);
        assert!(s.is_empty());
    }

    #[test]
    fn eviction_is_oldest_logical_tick_first() {
        let mut s = ObservationStore::new().with_quant(0.1).with_capacity(2);
        s.insert(Benchmark::Grep, HadoopVersion::V1, &benign(), &[0.1], 1.0, 0);
        s.insert(Benchmark::Grep, HadoopVersion::V1, &benign(), &[0.5], 2.0, 0);
        s.insert(Benchmark::Grep, HadoopVersion::V1, &benign(), &[0.9], 3.0, 0);
        assert_eq!(s.len(), 2);
        assert!(
            s.lookup(Benchmark::Grep, HadoopVersion::V1, &benign(), &[0.1]).is_none(),
            "the first-inserted entry is the evicted one"
        );
        assert!(s.lookup(Benchmark::Grep, HadoopVersion::V1, &benign(), &[0.9]).is_some());
        assert_eq!(s.counters().2, 1);
    }

    #[test]
    fn records_for_scans_exactly_one_prefix_in_cell_order() {
        let mut s = ObservationStore::new().with_quant(0.1);
        let faulty = ScenarioSpec::default().with_failures(0.05);
        s.insert(Benchmark::Grep, HadoopVersion::V1, &benign(), &[0.9, 0.1], 3.0, 0);
        s.insert(Benchmark::Grep, HadoopVersion::V1, &benign(), &[0.1, 0.9], 1.0, 0);
        s.insert(Benchmark::Grep, HadoopVersion::V2, &benign(), &[0.1, 0.9], 7.0, 0);
        s.insert(Benchmark::Grep, HadoopVersion::V1, &faulty, &[0.1, 0.9], 9.0, 0);
        s.insert(Benchmark::Terasort, HadoopVersion::V1, &benign(), &[0.1, 0.9], 5.0, 0);
        let recs = s.records_for(Benchmark::Grep, HadoopVersion::V1, &benign());
        let fs: Vec<f64> = recs.iter().map(|r| r.f).collect();
        assert_eq!(fs, vec![1.0, 3.0], "only the matching prefix, cell-ordered");
    }

    #[test]
    fn scenario_sig_separates_specs_and_is_stable() {
        let a = ScenarioSpec::default();
        let b = ScenarioSpec::default().with_failures(0.05);
        let c = ScenarioSpec::default().with_crash(120.0, 3);
        assert_eq!(scenario_sig(&a), scenario_sig(&a.clone()));
        assert_ne!(scenario_sig(&a), scenario_sig(&b));
        assert_ne!(scenario_sig(&a), scenario_sig(&c));
        assert_ne!(scenario_sig(&b), scenario_sig(&c));
    }
}
