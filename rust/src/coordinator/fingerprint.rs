//! Workload fingerprinting (Tuneful §3 / the recurring-jobs premise):
//! decide whether an incoming tuning request is "the same workload" as a
//! prior campaign, so its observations can be amortized.
//!
//! A fingerprint has two parts:
//!
//! * a **size axis** — `log2(input bytes)`, so a 2× input is distance 1
//!   regardless of absolute scale;
//! * a **shape vector** — scale-free ratios from the
//!   [`WorkloadProfile`] (selectivities, skew, compressibility,
//!   per-record CPU) concatenated with the *phase-profile vector* of a
//!   noise-free default-configuration simulation: each
//!   [`PhaseBreakdown`] phase as a fraction of total work, plus
//!   [`SimCounters`] data-flow ratios (map output / shuffle / final
//!   output bytes over input bytes). Two jobs that move data through
//!   the same phases in the same proportions fingerprint alike even if
//!   their profiles were measured differently.
//!
//! [`affinity`] maps a fingerprint pair into `(0, 1]`: exactly `1` iff
//! the fingerprints are identical, strictly decreasing in both shape
//! distance and size distance (property-tested: reflexive, and a 2×
//! input of the same shape scores strictly below an identical job).
//!
//! [`PhaseBreakdown`]: crate::sim::PhaseBreakdown
//! [`SimCounters`]: crate::sim::SimCounters
//! [`affinity`]: Fingerprint::affinity

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::cluster::ClusterSpec;
use crate::config::{HadoopVersion, ParameterSpace};
use crate::sim::{simulate, JobRunResult, ScenarioSpec, SimOptions};
use crate::workloads::{Benchmark, WorkloadProfile};

use super::campaign::profile_for;
use super::store::version_tag;

/// Weight of one doubling of input size in the affinity denominator:
/// a 2× input with an identical shape scores 1/(1+0.25) = 0.8.
pub const SIZE_WEIGHT: f64 = 0.25;

/// Workload fingerprint: size axis + scale-free shape vector.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint {
    /// `log2(input bytes)` — one unit per input doubling.
    pub log2_input: f64,
    /// Scale-free shape components (profile ratios + phase fractions +
    /// data-flow ratios), in a fixed documented order.
    pub shape: Vec<f64>,
}

impl Fingerprint {
    /// Build from a measured profile and one noise-free
    /// default-configuration run of the same workload.
    pub fn new(w: &WorkloadProfile, r: &JobRunResult) -> Fingerprint {
        let input = (w.input_bytes as f64).max(1.0);
        let mut shape = vec![
            // profile shape: what the job does per byte/record
            w.map_selectivity_bytes,
            w.map_selectivity_records,
            w.combiner_reduction,
            w.reduce_selectivity_bytes,
            w.partition_skew,
            w.compress_ratio,
            // per-record CPU on a log scale: 10× the ops is one unit
            (1.0 + w.map_cpu_ops_per_record).log10(),
            (1.0 + w.reduce_cpu_ops_per_record).log10(),
        ];
        // phase-profile vector: where the simulated time goes
        let p = &r.phases;
        let total = p.total().max(1e-9);
        shape.extend_from_slice(&[
            p.task_setup / total,
            p.map_read / total,
            p.map_cpu / total,
            p.map_spill / total,
            p.map_merge / total,
            p.shuffle / total,
            p.reduce_merge / total,
            p.reduce_cpu / total,
            p.output_write / total,
        ]);
        // data-flow ratios from the counters
        let c = &r.counters;
        shape.extend_from_slice(&[
            c.map_output_bytes as f64 / input,
            c.shuffled_bytes as f64 / input,
            c.output_bytes as f64 / input,
        ]);
        Fingerprint { log2_input: input.log2(), shape }
    }

    /// Match quality in `(0, 1]`: `1` iff identical; strictly decreasing
    /// in accumulated per-component relative shape distance and in size
    /// distance ([`SIZE_WEIGHT`] per input doubling). Shape distances
    /// are *summed*, not averaged — every component that disagrees digs
    /// the score further down, so workloads differing in several shape
    /// axes (different benchmarks) fall well below a merely-rescaled
    /// self. Fingerprints of different shape lengths never match
    /// (affinity 0).
    pub fn affinity(&self, other: &Fingerprint) -> f64 {
        if self.shape.len() != other.shape.len() || self.shape.is_empty() {
            return 0.0;
        }
        let size_d = (self.log2_input - other.log2_input).abs();
        let shape_d: f64 = self
            .shape
            .iter()
            .zip(&other.shape)
            .map(|(a, b)| {
                let denom = a.abs() + b.abs();
                if denom > 0.0 {
                    (a - b).abs() / denom
                } else {
                    0.0 // both zero: identical component
                }
            })
            .sum();
        1.0 / (1.0 + shape_d + SIZE_WEIGHT * size_d)
    }
}

/// The fingerprint of a benchmark's paper workload under `version`:
/// profile (fixed profiling seed 1000, like every campaign) + one
/// noise-free default-config simulation. Cached — the simulation runs
/// once per (benchmark, version) per process.
pub fn fingerprint_for(benchmark: Benchmark, version: HadoopVersion) -> Fingerprint {
    static CACHE: OnceLock<Mutex<BTreeMap<(Benchmark, u8), Fingerprint>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    let key = (benchmark, version_tag(version));
    // a poisoned lock only means another thread panicked mid-insert of a
    // by-construction-identical value: recover the map rather than panic
    let mut guard = match cache.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    if let Some(fp) = guard.get(&key) {
        return fp.clone();
    }
    let w = profile_for(benchmark, 1000);
    let space = ParameterSpace::for_version(version);
    let r = simulate(
        &ClusterSpec::paper_cluster(),
        &space.default_config(),
        &w,
        &SimOptions { seed: 1, noise: false, scenario: ScenarioSpec::default() },
    );
    let fp = Fingerprint::new(&w, &r);
    guard.insert(key, fp.clone());
    fp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fp_of(bench: Benchmark, bytes: u64) -> Fingerprint {
        // same rng seed and sample size for every call: the measured
        // profile *ratios* are identical per benchmark; only the target
        // size (and hence the simulated phase mix) varies
        let mut rng = Rng::seeded(7);
        let w = bench.profile_scaled(200_000, bytes, &mut rng);
        let space = ParameterSpace::v1();
        let r = simulate(
            &ClusterSpec::paper_cluster(),
            &space.default_config(),
            &w,
            &SimOptions { seed: 1, noise: false, scenario: ScenarioSpec::default() },
        );
        Fingerprint::new(&w, &r)
    }

    #[test]
    fn affinity_is_reflexive_and_scale_monotone() {
        let a = fp_of(Benchmark::Grep, 1 << 30);
        let b = fp_of(Benchmark::Grep, 1 << 31); // 2× input, same shape
        assert_eq!(a.affinity(&a), 1.0, "identical fingerprints score exactly 1");
        let ab = a.affinity(&b);
        assert!(ab < 1.0, "a 2× input matches with strictly lower affinity: {ab}");
        assert_eq!(ab, b.affinity(&a), "affinity is symmetric");
    }

    #[test]
    fn different_benchmarks_score_below_a_rescaled_self() {
        let g1 = fp_of(Benchmark::Grep, 1 << 30);
        let g2 = fp_of(Benchmark::Grep, 1 << 31);
        let t1 = fp_of(Benchmark::Terasort, 1 << 30);
        assert!(
            g1.affinity(&t1) < g1.affinity(&g2),
            "cross-benchmark affinity {} must stay below same-shape-rescaled {}",
            g1.affinity(&t1),
            g1.affinity(&g2)
        );
    }

    #[test]
    fn mismatched_shape_lengths_never_match() {
        let a = fp_of(Benchmark::Grep, 1 << 30);
        let mut b = a.clone();
        b.shape.pop();
        assert_eq!(a.affinity(&b), 0.0);
    }

    #[test]
    fn fingerprint_for_is_cached_and_deterministic() {
        let a = fingerprint_for(Benchmark::Terasort, HadoopVersion::V1);
        let b = fingerprint_for(Benchmark::Terasort, HadoopVersion::V1);
        assert_eq!(a, b);
    }
}
