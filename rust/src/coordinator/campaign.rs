//! Tuning campaigns: the orchestration layer that runs a tuner against a
//! benchmark on the simulated cluster and evaluates the outcome — the
//! equivalent of the SPSA process the paper runs on the NameNode (§6),
//! generalized over the comparison algorithms of §6.6.
//!
//! Every algorithm is a [`Tuner`](crate::tuner::Tuner) resolved from the
//! registry and driven through one budget-metered
//! [`EvalBroker`](crate::tuner::EvalBroker): identical observation budgets,
//! identical accounting, one convergence trace — the bespoke per-algorithm
//! dispatch this module used to carry is gone.

use crate::cluster::ClusterSpec;
use crate::config::{HadoopVersion, ParameterSpace};
use crate::sim::{simulate_batch_auto, ScenarioSpec, SimJob, SimOptions};
use crate::tuner::registry::{self, TunerContext};
use crate::tuner::{
    Budget, EvalBroker, EvalRecord, FrozenObjective, IterRecord, SimObjective,
};
use crate::util::rng::Rng;
use crate::util::stats::{mean, stddev};
use crate::workloads::{Benchmark, WorkloadProfile};

use super::pool::{resolve_workers, run_parallel};

// compat re-export: the constant moved to the registry with the tuners
pub use crate::tuner::registry::PROFILE_NOISE_SIGMA;

/// Tuning algorithm under test — a thin, enum-typed shim over the tuner
/// registry (experiment code matches on it; the registry owns behavior).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// No tuning: Hadoop defaults (the paper's baseline row).
    Default,
    /// The paper's contribution (Algorithm 1).
    Spsa,
    /// SPSA on the AOT surrogate model instead of the live system
    /// (extension; runs through the PJRT artifact when available).
    SpsaSurrogate,
    /// Starfish: profile + what-if (analytic model) + RRS.
    Starfish,
    /// PPABS: signature clustering + SA on a reduced space.
    Ppabs,
    /// MROnline-style hill climbing on the live system.
    HillClimb,
    /// Random search on the live system (ablation anchor).
    Random,
    /// Random-direction SA — the paper §7 noisy-gradient sibling.
    Rdsa,
    /// Nelder–Mead downhill simplex on the live system.
    NelderMead,
    /// TPE-style Bayesian optimization over the broker trace.
    Tpe,
}

impl Algo {
    pub fn all() -> [Algo; 10] {
        [
            Algo::Default,
            Algo::Spsa,
            Algo::SpsaSurrogate,
            Algo::Starfish,
            Algo::Ppabs,
            Algo::HillClimb,
            Algo::Random,
            Algo::Rdsa,
            Algo::NelderMead,
            Algo::Tpe,
        ]
    }

    /// Canonical registry name ([`crate::tuner::registry::find`]).
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Default => "default",
            Algo::Spsa => "spsa",
            Algo::SpsaSurrogate => "spsa-surrogate",
            Algo::Starfish => "starfish",
            Algo::Ppabs => "ppabs",
            Algo::HillClimb => "hillclimb",
            Algo::Random => "random",
            Algo::Rdsa => "rdsa",
            Algo::NelderMead => "nelder-mead",
            Algo::Tpe => "tpe",
        }
    }

    /// Display label (every output of this round-trips through
    /// [`Algo::from_name`], case-insensitively).
    pub fn label(&self) -> &'static str {
        match self {
            Algo::Default => "Default",
            Algo::Spsa => "SPSA",
            Algo::SpsaSurrogate => "SPSA-surrogate",
            Algo::Starfish => "Starfish",
            Algo::Ppabs => "PPABS",
            Algo::HillClimb => "HillClimb",
            Algo::Random => "Random",
            Algo::Rdsa => "RDSA",
            Algo::NelderMead => "NelderMead",
            Algo::Tpe => "TPE",
        }
    }

    /// Resolve through the registry: trims, matches canonical names,
    /// aliases and labels case-insensitively.
    pub fn from_name(s: &str) -> Option<Algo> {
        let entry = registry::find(s)?;
        Algo::all().into_iter().find(|a| a.name() == entry.name)
    }
}

/// One tuning trial: algorithm × benchmark × Hadoop version × seed, under
/// one shared live-observation budget.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    pub benchmark: Benchmark,
    pub version: HadoopVersion,
    pub algo: Algo,
    pub seed: u64,
    /// Live-observation budget the tuner may spend — the same number for
    /// every algorithm of a comparison, so best-found-vs-budget is the
    /// native currency (the paper's 2-obs/iter economy claim, §6.6).
    pub budget: Budget,
    /// Execution-substrate regime: live-system tuners observe the system
    /// under it, and the tuned/default verification runs execute under it
    /// too. Benign by default.
    pub scenario: ScenarioSpec,
}

/// Default per-trial budget: 90 observations ≈ 30 SPSA iterations of the
/// paper's estimator with gradient averaging (3 obs each).
pub const DEFAULT_TRIAL_BUDGET: u64 = 90;

impl TrialSpec {
    pub fn new(benchmark: Benchmark, version: HadoopVersion, algo: Algo, seed: u64) -> Self {
        TrialSpec {
            benchmark,
            version,
            algo,
            seed,
            budget: Budget::obs(DEFAULT_TRIAL_BUDGET),
            scenario: ScenarioSpec::default(),
        }
    }

    /// Builder: run this trial under a fault/heterogeneity scenario.
    pub fn with_scenario(mut self, scenario: ScenarioSpec) -> Self {
        self.scenario = scenario;
        self
    }

    /// Builder: cap the live-observation budget.
    pub fn with_budget(mut self, budget: Budget) -> Self {
        self.budget = budget;
        self
    }
}

/// Outcome of one trial.
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    pub spec: TrialSpec,
    pub tuned_theta: Vec<f64>,
    /// Mean / stddev execution time at the tuned configuration (5 noisy
    /// runs on the simulator).
    pub tuned_mean_s: f64,
    pub tuned_std_s: f64,
    /// Same for the default configuration.
    pub default_mean_s: f64,
    /// Live-system observations consumed while tuning (broker-metered;
    /// always ≤ `spec.budget.max_obs`).
    pub observations: u64,
    /// What-if model evaluations (model-based tuners only).
    pub model_evals: u64,
    /// Simulated profiling overhead (Starfish/PPABS; 0 for SPSA).
    pub profiling_overhead_s: f64,
    /// Tuner wall-clock on this machine.
    pub tuning_wall_ms: f64,
    /// Modeled wall-clock the tuning run cost, in simulated seconds
    /// (per-wave max-duration + dispatch overhead, plus charged external
    /// profiling — the broker's [`elapsed_model_time`]).
    ///
    /// [`elapsed_model_time`]: crate::tuner::EvalBroker::elapsed_model_time
    pub elapsed_model_s: f64,
    /// SPSA per-iteration history (empty for other algorithms).
    pub history: Vec<IterRecord>,
    /// The broker's uniform convergence trace — every observation served
    /// through the broker, in order. Empty for model-only tuners, and for
    /// PPABS, whose corpus profiling is metered via `EvalBroker::charge`
    /// (runs of *other* workloads never enter this trial's trace).
    pub eval_trace: Vec<EvalRecord>,
    /// `true` when the deployed `tuned_theta`'s claimed f replays a
    /// store-served value from an earlier campaign that no live
    /// observation of this run matched or beat — the deployment is
    /// noise-frozen (see [`ObsSource::Store`]). Always `false` for cold
    /// (service-less) trials.
    ///
    /// [`ObsSource::Store`]: crate::tuner::ObsSource
    pub noise_frozen: bool,
    /// Observations served free by the cross-campaign store (warm-start
    /// seeds + store-tier lookup hits). 0 for cold trials.
    pub store_hits: u64,
}

impl TrialOutcome {
    /// The paper's headline metric: % decrease vs. the default config.
    pub fn pct_decrease(&self) -> f64 {
        100.0 * (self.default_mean_s - self.tuned_mean_s) / self.default_mean_s
    }
}

/// Build the workload profile for a benchmark by really running it on
/// sampled data. Profiles are cached per (benchmark, seed): the engine run
/// costs ~150 ms and campaigns request the same profile for every trial
/// (§Perf optimization 1 — see EXPERIMENTS.md).
pub fn profile_for(benchmark: Benchmark, seed: u64) -> WorkloadProfile {
    use std::collections::BTreeMap;
    use std::sync::{Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<BTreeMap<(Benchmark, u64), WorkloadProfile>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    if let Some(p) = cache.lock().unwrap().get(&(benchmark, seed)) {
        return p.clone();
    }
    let mut rng = Rng::seeded(seed);
    let p = benchmark.paper_profile(&mut rng);
    cache.lock().unwrap().insert((benchmark, seed), p.clone());
    p
}

/// Evaluate a θ on the simulator with `n` noisy runs under `scenario`;
/// returns (mean, std). The runs are independent verification jobs, so
/// they fan across the worker pool (`HSPSA_WORKERS` knob); per-run seeds
/// are fixed up front, so the statistics are identical at any worker
/// count. Failed runs (max.attempts exhausted) carry the objective-layer
/// penalty so robustness tables surface them.
pub fn evaluate_theta(
    space: &ParameterSpace,
    cluster: &ClusterSpec,
    w: &WorkloadProfile,
    theta: &[f64],
    n: u64,
    seed: u64,
    scenario: &ScenarioSpec,
) -> (f64, f64) {
    let cfg = space.materialize(theta);
    let jobs: Vec<SimJob> = (0..n)
        .map(|i| SimJob {
            config: cfg.clone(),
            opts: SimOptions { seed: seed ^ (i + 1), noise: true, scenario: scenario.clone() },
        })
        .collect();
    let runs: Vec<f64> = simulate_batch_auto(cluster, jobs, w)
        .iter()
        .map(|r| crate::tuner::Metric::ExecTime.score(r))
        .collect();
    (mean(&runs), stddev(&runs))
}

/// Cross-campaign warm-start context for one trial — assembled by the
/// service layer ([`coordinator::service`]) from the observation store's
/// records for campaigns whose workload fingerprint matched this request.
///
/// [`coordinator::service`]: crate::coordinator::service
#[derive(Clone, Debug)]
pub struct WarmStart {
    /// Prior observations `(full-dimensional θ, f)`, noise-frozen at
    /// their original draw. Ingested into the broker as free
    /// [`ObsSource::Store`] records (and, for `Quantized`-policy tuners,
    /// attached as a store cache tier).
    ///
    /// [`ObsSource::Store`]: crate::tuner::ObsSource
    pub records: Vec<(Vec<f64>, f64)>,
    /// θ-cell size the records' store was keyed under (coarser than the
    /// broker memo's 1e-6, so cross-seed revisits actually hit).
    pub store_quant: f64,
    /// Dimension-pruning mask (Tuneful §3): `true` freezes that
    /// parameter at its default for the whole trial. Empty = no pruning.
    /// Only meaningful for direct-search tuners — model-based tuners
    /// (Starfish, PPABS, surrogate SPSA) need the full space for their
    /// what-if features, and the service never prunes them.
    pub frozen: Vec<bool>,
}

impl WarmStart {
    pub fn new(records: Vec<(Vec<f64>, f64)>, store_quant: f64) -> WarmStart {
        WarmStart { records, store_quant, frozen: Vec::new() }
    }
}

/// Expand a reduced θ (one entry per non-frozen coordinate, in index
/// order) back to the full space: frozen coordinates come from
/// `template`. With an all-false (or empty) mask this is the identity.
pub fn expand_theta(template: &[f64], frozen: &[bool], reduced: &[f64]) -> Vec<f64> {
    if frozen.iter().all(|&fz| !fz) {
        return reduced.to_vec();
    }
    let mut full = template.to_vec();
    let mut j = 0;
    for (i, &fz) in frozen.iter().enumerate() {
        if !fz && j < reduced.len() {
            full[i] = reduced[j];
            j += 1;
        }
    }
    full
}

/// Run one tuning trial end to end: resolve the algorithm from the
/// registry, let it spend the trial's budget through a metered broker,
/// then verify tuned vs default on the simulator.
pub fn run_trial(spec: &TrialSpec) -> TrialOutcome {
    run_trial_warmed(spec, None)
}

/// [`run_trial`], optionally warm-started from a cross-campaign
/// [`WarmStart`]: prior records are served to the tuner for free (store
/// tier + ingested incumbent seeds, both flagged [`ObsSource::Store`]),
/// and a pruning mask shrinks the search space the tuner sees — the
/// objective still evaluates full-dimensional configurations via
/// [`FrozenObjective`], and every θ in the returned outcome/trace is
/// expanded back to the full space. With `warm == None` this is
/// bit-identical to the historical cold path.
///
/// [`ObsSource::Store`]: crate::tuner::ObsSource
/// [`FrozenObjective`]: crate::tuner::FrozenObjective
pub fn run_trial_warmed(spec: &TrialSpec, warm: Option<&WarmStart>) -> TrialOutcome {
    let space = ParameterSpace::for_version(spec.version);
    let cluster = ClusterSpec::paper_cluster();
    // fixed profiling seed: all algorithms tune the *same* workload
    let w = profile_for(spec.benchmark, 1000);
    let ctx = TunerContext {
        version: spec.version,
        cluster: cluster.clone(),
        workload: w.clone(),
    };
    let tuner = registry::create(spec.algo.name(), &ctx)
        .expect("every Algo maps to a registry entry");

    let full_dim = space.dim();
    let template = space.default_theta();
    // honor the pruning mask only when it is well-formed and keeps ≥ 1 dim
    let frozen: Vec<bool> = match warm {
        Some(ws)
            if ws.frozen.len() == full_dim
                && ws.frozen.iter().any(|&fz| fz)
                && !ws.frozen.iter().all(|&fz| fz) =>
        {
            ws.frozen.clone()
        }
        _ => vec![false; full_dim],
    };
    let pruned = frozen.iter().any(|&fz| fz);
    let search_space = if pruned {
        let keep: Vec<bool> = frozen.iter().map(|&fz| !fz).collect();
        space.subspace(&keep)
    } else {
        space.clone()
    };

    // lint:allow(wall-clock): tuning_wall_ms is reporting-only (walltime table) — never feeds modeled results or seeds
    let t0 = std::time::Instant::now();
    let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), spec.seed)
        .with_scenario(spec.scenario.clone());
    // the freeze adapter is an identity layer when nothing is pruned, so
    // cold trials take the exact same code path (and values) as before
    let mut fobj = FrozenObjective::new(&mut obj, template.clone(), &frozen);
    let mut broker =
        EvalBroker::new(&mut fobj, spec.budget).with_cache(tuner.cache_policy());

    if let Some(ws) = warm {
        // project prior full-dim records onto the reduced view: under
        // pruning only records whose frozen coordinates share the
        // template's store cell describe the function the tuner explores
        let quant = if ws.store_quant > 0.0 { ws.store_quant } else { 1e-6 };
        let cell = |x: f64| (x / quant).round() as i64;
        let reduced: Vec<(Vec<f64>, f64)> = ws
            .records
            .iter()
            .filter(|(t, _)| {
                t.len() == full_dim
                    && frozen
                        .iter()
                        .zip(t.iter().zip(&template))
                        .all(|(&fz, (&x, &d))| !fz || cell(x) == cell(d))
            })
            .map(|(t, f)| {
                let r: Vec<f64> = t
                    .iter()
                    .zip(&frozen)
                    .filter(|(_, &fz)| !fz)
                    .map(|(&x, _)| x)
                    .collect();
                (r, *f)
            })
            .collect();
        broker = broker.with_store_tier(quant, &reduced);
        // seed the trace: every prior record replays for free at obs 0,
        // so best-so-far starts at the matched campaigns' incumbent
        for (t, f) in &reduced {
            broker.ingest(t, *f);
        }
    }

    let mut out = tuner.tune(&mut broker, &search_space, spec.seed);
    // Satellite bugfix: a store-served incumbent can beat everything the
    // tuner measured live — deploy the better configuration, but flag it
    // noise-frozen (its f was observed under an earlier campaign's noise
    // stream and never re-verified here).
    if broker.best_noise_frozen() {
        if let Some((bt, bf)) = broker.best() {
            // NaN/∞-safe: replace unless the tuner's claim is already ≤
            if out.best_f.is_nan() || out.best_f > bf {
                out.best_theta = bt.to_vec();
                out.best_f = bf;
                out.noise_frozen = true;
            }
        }
    }
    let noise_frozen = out.noise_frozen;
    let store_hits = broker.store_hits();
    let observations = broker.evals_used();
    let elapsed_model_s = broker.elapsed_model_time();
    let mut eval_trace = broker.take_trace();
    let tuning_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(
        observations <= spec.budget.max_obs,
        "{} overspent its budget: {observations} > {}",
        spec.algo.label(),
        spec.budget.max_obs
    );

    // everything leaving this function is full-dimensional
    let tuned_theta = expand_theta(&template, &frozen, &out.best_theta);
    if pruned {
        for r in &mut eval_trace {
            r.theta = expand_theta(&template, &frozen, &r.theta);
        }
    }

    const EVAL_SEED: u64 = 0xE7A1;
    let (tuned_mean_s, tuned_std_s) = evaluate_theta(
        &space,
        &cluster,
        &w,
        &tuned_theta,
        5,
        spec.seed ^ EVAL_SEED,
        &spec.scenario,
    );
    let (default_mean_s, _) = evaluate_theta(
        &space,
        &cluster,
        &w,
        &space.default_theta(),
        5,
        spec.seed ^ EVAL_SEED,
        &spec.scenario,
    );

    TrialOutcome {
        spec: spec.clone(),
        tuned_theta,
        tuned_mean_s,
        tuned_std_s,
        default_mean_s,
        observations,
        model_evals: out.model_evals,
        profiling_overhead_s: out.profiling_overhead_s,
        tuning_wall_ms,
        elapsed_model_s,
        history: out.history,
        eval_trace,
        noise_frozen,
        store_hits,
    }
}

/// Run many trials across the worker pool (leader/worker topology).
/// Worker count honors `HSPSA_WORKERS` (1 = fully sequential).
pub fn run_campaign(specs: Vec<TrialSpec>) -> Vec<TrialOutcome> {
    let jobs: Vec<Box<dyn FnOnce() -> TrialOutcome + Send>> = specs
        .into_iter()
        .map(|s| Box::new(move || run_trial(&s)) as _)
        .collect();
    run_parallel(jobs, resolve_workers(None))
}

// ---------------------------------------------------------------------------
// campaign scheduler: one shared wall-clock budget across the registry
// ---------------------------------------------------------------------------

/// How a [`CampaignScheduler`] splits its shared wall-clock budget among
/// its tuners.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// Every tuner gets `total / n` modeled seconds up front.
    Equal,
    /// Successive halving: the budget is spent rung by rung; after each
    /// rung the worst half of the survivors (ranked by best *observed* f,
    /// ties broken by registry order) is culled, and the culled tuners'
    /// **unspent** allocation flows back into the pool the remaining
    /// rungs share — reinvested in the survivors.
    SuccessiveHalving,
}

/// Per-tuner observation guard of the scheduler: the time axis is the
/// intended stop, but a pathological cost model (near-zero durations)
/// must not be able to buy unbounded simulations.
pub const SCHEDULER_OBS_GUARD: u64 = 2048;

/// One tuner's result under a [`CampaignScheduler`].
#[derive(Clone, Debug)]
pub struct SchedulerOutcome {
    pub algo: Algo,
    /// Cumulative modeled seconds this tuner was allocated.
    pub allocated_s: f64,
    /// Modeled seconds actually spent (time is checked pre-dispatch, so
    /// this exceeds `allocated_s` by at most `max_wave_s`).
    pub elapsed_s: f64,
    /// Costliest single wave of the run — the overshoot bound.
    pub max_wave_s: f64,
    pub observations: u64,
    pub batches: u64,
    /// Configuration the tuner would deploy.
    pub best_theta: Vec<f64>,
    /// Best *observed* f (∞ for tuners that never observe live — they
    /// rank last under every policy: in the wall-clock frame an
    /// unverified model optimum has banked nothing yet).
    pub best_f: f64,
    /// Live observations spent when the best was first observed.
    pub obs_to_best: u64,
    /// Modeled seconds elapsed when the best was first observed — the
    /// time-to-best metric.
    pub time_to_best: f64,
    /// Rung at which `SuccessiveHalving` culled this tuner (`None` =
    /// survived to the end; always `None` under `Equal`).
    pub culled_at_rung: Option<u32>,
    /// Full broker trace of the tuner's final (longest) run: the
    /// time-to-best curve, via [`EvalRecord::model_time`].
    pub trace: Vec<EvalRecord>,
}

/// Runs a set of tuners — by default the whole registry — against one
/// benchmark under ONE shared modeled wall-clock budget, allocating
/// per-tuner time by [`SchedulerPolicy`] and recording per-tuner
/// time-to-best curves. This is the comparison frame of the successor
/// literature (Tuneful, Bao et al.): *time-to-good-configuration*, where
/// a 64-probe wave costs one wave, not 64 observations.
///
/// **Resume by replay.** Tuners expose no pause/resume across the
/// registry, but every one of them is deterministic given (seed,
/// objective seed stream): re-running with a *larger* time budget
/// reproduces the same trajectory prefix bit-exactly and extends it
/// (tested). `SuccessiveHalving` therefore extends a survivor's run by
/// re-running it at its cumulative allocation; the campaign charges each
/// tuner's **final** elapsed time — the replay is a simulation
/// bookkeeping trick, never double-billed.
#[derive(Clone)]
pub struct CampaignScheduler {
    pub benchmark: Benchmark,
    pub version: HadoopVersion,
    pub seed: u64,
    pub scenario: ScenarioSpec,
    pub algos: Vec<Algo>,
    /// Shared budget: modeled seconds across ALL tuners together.
    pub total_model_time: f64,
    /// Per-tuner observation guard (see [`SCHEDULER_OBS_GUARD`]).
    pub max_obs_per_tuner: u64,
    pub policy: SchedulerPolicy,
}

impl CampaignScheduler {
    pub fn new(
        benchmark: Benchmark,
        version: HadoopVersion,
        seed: u64,
        total_model_time: f64,
    ) -> Self {
        assert!(total_model_time > 0.0, "scheduler needs a positive time budget");
        CampaignScheduler {
            benchmark,
            version,
            seed,
            scenario: ScenarioSpec::default(),
            algos: Algo::all().to_vec(),
            total_model_time,
            max_obs_per_tuner: SCHEDULER_OBS_GUARD,
            policy: SchedulerPolicy::Equal,
        }
    }

    pub fn with_policy(mut self, policy: SchedulerPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_algos(mut self, algos: Vec<Algo>) -> Self {
        assert!(!algos.is_empty());
        self.algos = algos;
        self
    }

    pub fn with_scenario(mut self, scenario: ScenarioSpec) -> Self {
        self.scenario = scenario;
        self
    }

    pub fn with_obs_guard(mut self, max_obs_per_tuner: u64) -> Self {
        self.max_obs_per_tuner = max_obs_per_tuner.max(1);
        self
    }

    /// Number of allocation rounds: 1 for `Equal`; for halving, ⌈log₂ n⌉
    /// rungs — culls fire after every rung but the last, so the final
    /// rung is run by TWO finalists (n → … → 3 → 2), never a walkover:
    /// the last cull decision is itself made on fully-funded runs.
    fn rungs(&self) -> usize {
        match self.policy {
            SchedulerPolicy::Equal => 1,
            SchedulerPolicy::SuccessiveHalving => {
                let (mut r, mut k) = (0, self.algos.len());
                while k > 1 {
                    r += 1;
                    k = k.div_ceil(2);
                }
                r.max(1)
            }
        }
    }

    /// One tuner at one cumulative time allocation, from scratch (the
    /// replay primitive). Same plumbing as [`run_trial`], but the budget
    /// is wall-clock-first: unlimited-ish observations, `alloc_s` modeled
    /// seconds.
    fn run_one(&self, algo: Algo, alloc_s: f64) -> SchedulerOutcome {
        let space = ParameterSpace::for_version(self.version);
        let cluster = ClusterSpec::paper_cluster();
        let w = profile_for(self.benchmark, 1000);
        let ctx = TunerContext {
            version: self.version,
            cluster: cluster.clone(),
            workload: w.clone(),
        };
        let tuner = registry::create(algo.name(), &ctx)
            .expect("every Algo maps to a registry entry");
        let mut obj = SimObjective::new(space.clone(), cluster, w, self.seed)
            .with_scenario(self.scenario.clone());
        let budget = Budget::obs(self.max_obs_per_tuner).with_model_time(alloc_s);
        let mut broker = EvalBroker::new(&mut obj, budget).with_cache(tuner.cache_policy());
        let out = tuner.tune(&mut broker, &space, self.seed);

        let (observations, batches) = (broker.evals_used(), broker.batches_used());
        let (elapsed_s, max_wave_s) = (broker.elapsed_model_time(), broker.max_batch_cost());
        let trace = broker.take_trace();
        let (mut best_f, mut obs_to_best, mut time_to_best) = (f64::INFINITY, 0, 0.0);
        for r in &trace {
            if r.f < best_f {
                best_f = r.f;
                obs_to_best = r.obs;
                time_to_best = r.model_time;
            }
        }
        SchedulerOutcome {
            algo,
            allocated_s: alloc_s,
            elapsed_s,
            max_wave_s,
            observations,
            batches,
            best_theta: out.best_theta,
            best_f,
            obs_to_best,
            time_to_best,
            culled_at_rung: None,
            trace,
        }
    }

    /// Run the campaign. Outcomes come back in `algos` order, culled
    /// tuners included (with their partial results and cull rung).
    pub fn run(&self) -> Vec<SchedulerOutcome> {
        let n = self.algos.len();
        let rungs = self.rungs();
        let mut alloc = vec![0.0_f64; n];
        let mut culled: Vec<Option<u32>> = vec![None; n];
        let mut outcomes: Vec<Option<SchedulerOutcome>> = (0..n).map(|_| None).collect();
        let mut pool = self.total_model_time;
        let mut survivors: Vec<usize> = (0..n).collect();

        for rung in 0..rungs {
            // this rung spends an equal slice of what is left — including
            // everything reclaimed from earlier culls
            let share = pool / (rungs - rung) as f64;
            pool -= share;
            let per = share / survivors.len() as f64;
            for &i in &survivors {
                alloc[i] += per;
            }

            // (re)run every survivor at its cumulative allocation —
            // resume by replay (see the type docs); independent runs fan
            // across the worker pool
            let jobs: Vec<Box<dyn FnOnce() -> SchedulerOutcome + Send>> = survivors
                .iter()
                .map(|&i| {
                    let sched = self.clone();
                    let (algo, a) = (self.algos[i], alloc[i]);
                    Box::new(move || sched.run_one(algo, a)) as _
                })
                .collect();
            let results = run_parallel(jobs, resolve_workers(None));
            for (&i, out) in survivors.iter().zip(results) {
                outcomes[i] = Some(out);
            }

            if rung + 1 < rungs && survivors.len() > 1 {
                let ranked = rank_by_observed_f(&survivors, |i| {
                    outcomes[i].as_ref().map_or(f64::INFINITY, |o| o.best_f)
                });
                let keep = ranked.len().div_ceil(2);
                for &i in &ranked[keep..] {
                    culled[i] = Some(rung as u32);
                    let spent = outcomes[i].as_ref().expect("ran this rung").elapsed_s;
                    // reinvest the culled tuner's remaining time: the
                    // unspent grant moves from its allocation back into
                    // the pool, so Σ allocations never exceeds the total
                    // budget (a run may overshoot its allocation by one
                    // wave — never reclaim a negative remainder)
                    let unspent = (alloc[i] - spent).max(0.0);
                    pool += unspent;
                    alloc[i] -= unspent;
                }
                survivors = ranked[..keep].to_vec();
                survivors.sort_unstable(); // registry order, deterministic
            }
        }

        (0..n)
            .map(|i| {
                let mut o = outcomes[i].take().expect("every tuner ran at least rung 0");
                o.culled_at_rung = culled[i];
                o.allocated_s = alloc[i];
                o
            })
            .collect()
    }
}

/// Rank candidate indices ascending by observed f, ties (and everything
/// non-finite) broken by index — the `SuccessiveHalving` cull order. NaN
/// keys map to +∞ first: a poisoned trial must rank last (and be culled),
/// not panic the rung or — under `total_cmp`, where NaN sorts *above*
/// +∞ — shuffle legitimate ∞-ranked tuners.
fn rank_by_observed_f(candidates: &[usize], best_f_of: impl Fn(usize) -> f64) -> Vec<usize> {
    let key = |i: usize| {
        let f = best_f_of(i);
        if f.is_nan() {
            f64::INFINITY
        } else {
            f
        }
    };
    let mut ranked = candidates.to_vec();
    ranked.sort_by(|&a, &b| key(a).total_cmp(&key(b)).then(a.cmp(&b)));
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rung_cull_rank_is_nan_and_inf_proof() {
        // one poisoned trial (NaN), one that never observed (+∞), dupes —
        // the cull order stays total, deterministic and panic-free
        let fs = [0.5, f64::NAN, 0.2, f64::INFINITY, f64::NAN, 0.2];
        let idx: Vec<usize> = (0..fs.len()).collect();
        let ranked = rank_by_observed_f(&idx, |i| fs[i]);
        assert_eq!(ranked, vec![2, 5, 0, 1, 3, 4]);
        // the worst half culled by `run()` is the NaN/∞ tail, never a
        // finite performer
        let keep = ranked.len().div_ceil(2);
        assert!(ranked[..keep].iter().all(|&i| fs[i].is_finite()));
    }

    #[test]
    fn algo_label_round_trips_case_insensitively() {
        for algo in Algo::all() {
            assert_eq!(Algo::from_name(algo.label()), Some(algo), "{}", algo.label());
            assert_eq!(
                Algo::from_name(&algo.label().to_uppercase()),
                Some(algo),
                "uppercased {}",
                algo.label()
            );
            assert_eq!(Algo::from_name(&format!("  {} ", algo.name())), Some(algo));
        }
        // legacy aliases stay accepted
        assert_eq!(Algo::from_name("hill"), Some(Algo::HillClimb));
        assert_eq!(Algo::from_name("mronline"), Some(Algo::HillClimb));
        assert_eq!(Algo::from_name("surrogate"), Some(Algo::SpsaSurrogate));
        assert_eq!(Algo::from_name("simplex"), Some(Algo::NelderMead));
        assert_eq!(Algo::from_name("bayesopt"), Some(Algo::Tpe));
        assert_eq!(Algo::from_name("rd-sa"), Some(Algo::Rdsa));
        assert_eq!(Algo::from_name("bogus"), None);
    }

    #[test]
    fn spsa_trial_beats_default() {
        let spec = TrialSpec::new(Benchmark::Terasort, HadoopVersion::V1, Algo::Spsa, 5);
        let out = run_trial(&spec);
        assert!(out.pct_decrease() > 30.0, "decrease {:.1}%", out.pct_decrease());
        // 3 obs per iteration, whole iterations only, within budget
        assert_eq!(out.history.len() as u64 * 3, out.observations);
        assert!(out.observations <= out.spec.budget.max_obs);
        assert!(out.observations >= out.spec.budget.max_obs / 2, "barely tuned");
        assert_eq!(out.profiling_overhead_s, 0.0);
        // the uniform trace mirrors the broker accounting
        assert_eq!(out.eval_trace.len() as u64, out.observations);
    }

    #[test]
    fn default_trial_is_identity() {
        let spec = TrialSpec::new(Benchmark::Grep, HadoopVersion::V2, Algo::Default, 1);
        let out = run_trial(&spec);
        assert!((out.pct_decrease()).abs() < 1e-9);
        assert_eq!(out.observations, 0);
        assert!(out.eval_trace.is_empty());
    }

    #[test]
    fn campaign_runs_parallel_trials() {
        let specs = vec![
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Spsa, 1),
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Random, 1),
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Default, 1),
        ];
        let out = run_campaign(specs);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].spec.algo, Algo::Spsa);
        assert_eq!(out[2].spec.algo, Algo::Default);
        // both live-system tuners improve on the default for bigram
        assert!(out[0].pct_decrease() > 20.0, "spsa {:.1}%", out[0].pct_decrease());
        assert!(out[1].pct_decrease() > 0.0, "random {:.1}%", out[1].pct_decrease());
        // random search spends the whole shared budget, to the observation
        assert_eq!(out[1].observations, out[1].spec.budget.max_obs);
    }

    #[test]
    fn scenario_trial_tunes_under_faults() {
        // SPSA observing a faulty heterogeneous cluster must still beat the
        // default configuration evaluated under the same scenario.
        let scenario = ScenarioSpec::default()
            .with_failures(0.05)
            .with_max_attempts(10)
            .with_slow_node(2, 0.6)
            .with_slow_node(5, 0.7)
            .with_speculation(true);
        let spec = TrialSpec::new(Benchmark::Terasort, HadoopVersion::V1, Algo::Spsa, 5)
            .with_scenario(scenario);
        let out = run_trial(&spec);
        assert!(
            out.pct_decrease() > 20.0,
            "under faults only {:.1}% decrease",
            out.pct_decrease()
        );
    }

    #[test]
    fn starfish_trial_reports_overheads() {
        let spec = TrialSpec::new(Benchmark::InvertedIndex, HadoopVersion::V1, Algo::Starfish, 2);
        let out = run_trial(&spec);
        assert!(out.profiling_overhead_s > 0.0);
        assert!(out.model_evals > 100);
        assert!(out.pct_decrease() > 0.0);
        assert_eq!(out.observations, 1, "starfish profiles exactly once");
    }

    // noise-free default-config duration — sizes time budgets in
    // multiples of a real wave, keeping the tests magnitude-independent
    use crate::experiments::walltime::calib_s;

    #[test]
    fn equal_policy_splits_the_shared_clock_evenly() {
        // ~6 default-duration waves of clock per tuner
        let per = 6.0 * (calib_s(Benchmark::Grep, HadoopVersion::V1) + 5.0);
        let total = 4.0 * per;
        let sched = CampaignScheduler::new(Benchmark::Grep, HadoopVersion::V1, 3, total)
            .with_algos(vec![Algo::Default, Algo::Spsa, Algo::Random, Algo::HillClimb]);
        let outs = sched.run();
        assert_eq!(outs.len(), 4);
        for o in &outs {
            assert!((o.allocated_s - per).abs() < 1e-9, "{:?}", o.algo);
            assert!(o.culled_at_rung.is_none(), "Equal never culls");
            assert!(
                o.elapsed_s <= o.allocated_s + o.max_wave_s,
                "{:?} overshot by more than one wave: {} > {} + {}",
                o.algo,
                o.elapsed_s,
                o.allocated_s,
                o.max_wave_s
            );
        }
        // live tuners spend the clock; Default never observes
        assert_eq!(outs[0].observations, 0);
        assert_eq!(outs[0].elapsed_s, 0.0);
        assert!(outs[0].best_f.is_infinite());
        for o in &outs[1..] {
            assert!(o.observations > 0, "{:?} never observed", o.algo);
            assert!(o.best_f.is_finite());
            assert!(o.time_to_best > 0.0 && o.time_to_best <= o.elapsed_s);
            assert!(o.obs_to_best >= 1 && o.obs_to_best <= o.observations);
        }
        // in the wall-clock frame random's 64-probe waves buy far more
        // observations per second than SPSA's 3-probe waves
        let spsa = outs.iter().find(|o| o.algo == Algo::Spsa).unwrap();
        let random = outs.iter().find(|o| o.algo == Algo::Random).unwrap();
        assert!(
            random.observations > spsa.observations,
            "random {} obs vs spsa {} obs under one clock",
            random.observations,
            spsa.observations
        );
    }

    #[test]
    fn successive_halving_reinvests_culled_tuners_remaining_time() {
        // The acceptance assertion. Four tuners, two rungs (4 → 2 → 1).
        // Rung 0 grants each T/8 of the total T. `Default` never observes
        // (best_f = ∞, elapsed 0), so it is culled first and its FULL T/8
        // flows back into the pool. Without reclamation a survivor's final
        // allocation would be T/8 + (T/2)/2 = 0.375·T; with the ≥ T/8
        // reclaim it is ≥ T/8 + (T/2 + T/8)/2 = 0.4375·T. Asserting
        // > 0.42·T pins that culled time really is reinvested.
        let total = 8000.0;
        let sched = CampaignScheduler::new(Benchmark::Grep, HadoopVersion::V1, 3, total)
            .with_algos(vec![Algo::Default, Algo::Spsa, Algo::Random, Algo::HillClimb])
            .with_policy(SchedulerPolicy::SuccessiveHalving);
        let outs = sched.run();
        assert_eq!(outs.len(), 4, "culled tuners still report partial results");

        let default_o = &outs[0];
        assert_eq!(default_o.algo, Algo::Default);
        assert_eq!(default_o.culled_at_rung, Some(0), "∞-ranked tuner culled at rung 0");
        assert_eq!(default_o.elapsed_s, 0.0);
        assert_eq!(
            default_o.allocated_s, 0.0,
            "a culled tuner's unspent grant must move back to the pool"
        );

        let survivors: Vec<_> = outs.iter().filter(|o| o.culled_at_rung.is_none()).collect();
        assert_eq!(survivors.len(), 2, "4 → 2 survivors over two rungs");
        for s in &survivors {
            assert!(
                s.allocated_s > 0.42 * total,
                "{:?} got {:.0}s of {total}s — culled time was not reinvested",
                s.algo,
                s.allocated_s
            );
        }
        // the budget stays a budget: nothing allocated out of thin air
        let granted: f64 = outs.iter().map(|o| o.allocated_s).sum();
        assert!(granted <= total + 1e-6, "allocated {granted} > total {total}");
    }

    #[test]
    fn extending_a_time_budget_replays_the_trajectory_prefix() {
        // The resume-by-replay contract SuccessiveHalving rests on:
        // re-running a tuner with a larger time allocation reproduces the
        // shorter run's observation stream bit-exactly and extends it.
        let run_with = |t: f64| {
            CampaignScheduler::new(Benchmark::Grep, HadoopVersion::V1, 5, t)
                .with_algos(vec![Algo::Spsa])
                .run()
                .remove(0)
        };
        let short = run_with(1200.0);
        let long = run_with(2400.0);
        assert!(
            long.trace.len() >= short.trace.len(),
            "doubling the clock shrank the run"
        );
        for (a, b) in short.trace.iter().zip(&long.trace) {
            assert_eq!(a.f, b.f, "replayed observation diverged");
            assert_eq!(a.theta, b.theta);
            assert_eq!(a.obs, b.obs);
            assert_eq!(a.model_time, b.model_time);
        }
    }

    #[test]
    fn every_algo_runs_under_one_small_budget() {
        // The whole registry through run_trial at a tight shared budget:
        // nothing overspends (run_trial asserts) and outcomes are sane.
        for algo in Algo::all() {
            let spec = TrialSpec::new(Benchmark::Grep, HadoopVersion::V1, algo, 3)
                .with_budget(Budget::obs(24));
            let out = run_trial(&spec);
            assert!(out.observations <= 24, "{}", algo.label());
            assert!(out.tuned_mean_s > 0.0, "{}", algo.label());
        }
    }
}
