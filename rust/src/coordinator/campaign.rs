//! Tuning campaigns: the orchestration layer that runs a tuner against a
//! benchmark on the simulated cluster and evaluates the outcome — the
//! equivalent of the SPSA process the paper runs on the NameNode (§6),
//! generalized over the comparison algorithms of §6.6.

use crate::baselines::{
    hill_climb, random_search, starfish_tune, training_corpus, CostObjective,
    HillClimbConfig, Ppabs, RrsConfig, RustWhatIf,
};
use crate::cluster::ClusterSpec;
use crate::config::{HadoopVersion, ParameterSpace};
use crate::sim::{simulate_batch_auto, ScenarioSpec, SimJob, SimOptions};
use crate::tuner::{IterRecord, SimObjective, Spsa, SpsaConfig};
use crate::util::rng::Rng;
use crate::util::stats::{mean, stddev};
use crate::whatif::ClusterFeatures;
use crate::workloads::{Benchmark, WorkloadProfile};

use super::pool::{resolve_workers, run_parallel};

/// Tuning algorithm under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// No tuning: Hadoop defaults (the paper's baseline row).
    Default,
    /// The paper's contribution (Algorithm 1).
    Spsa,
    /// SPSA on the AOT surrogate model instead of the live system
    /// (extension; runs through the PJRT artifact when available).
    SpsaSurrogate,
    /// Starfish: profile + what-if (analytic model) + RRS.
    Starfish,
    /// PPABS: signature clustering + SA on a reduced space.
    Ppabs,
    /// MROnline-style hill climbing on the live system.
    HillClimb,
    /// Random search on the live system (ablation anchor).
    Random,
}

impl Algo {
    pub fn label(&self) -> &'static str {
        match self {
            Algo::Default => "Default",
            Algo::Spsa => "SPSA",
            Algo::SpsaSurrogate => "SPSA-surrogate",
            Algo::Starfish => "Starfish",
            Algo::Ppabs => "PPABS",
            Algo::HillClimb => "HillClimb",
            Algo::Random => "Random",
        }
    }

    pub fn from_name(s: &str) -> Option<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "default" => Some(Algo::Default),
            "spsa" => Some(Algo::Spsa),
            "spsa-surrogate" | "surrogate" => Some(Algo::SpsaSurrogate),
            "starfish" => Some(Algo::Starfish),
            "ppabs" => Some(Algo::Ppabs),
            "hill" | "hillclimb" | "mronline" => Some(Algo::HillClimb),
            "random" => Some(Algo::Random),
            _ => None,
        }
    }
}

/// One tuning trial: algorithm × benchmark × Hadoop version × seed.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    pub benchmark: Benchmark,
    pub version: HadoopVersion,
    pub algo: Algo,
    pub seed: u64,
    /// SPSA iteration budget (other live-system tuners get 2× this many
    /// observations so budgets are comparable).
    pub iters: u64,
    /// Execution-substrate regime: live-system tuners observe the system
    /// under it, and the tuned/default verification runs execute under it
    /// too. Benign by default.
    pub scenario: ScenarioSpec,
}

impl TrialSpec {
    pub fn new(benchmark: Benchmark, version: HadoopVersion, algo: Algo, seed: u64) -> Self {
        TrialSpec {
            benchmark,
            version,
            algo,
            seed,
            iters: 30,
            scenario: ScenarioSpec::default(),
        }
    }

    /// Builder: run this trial under a fault/heterogeneity scenario.
    pub fn with_scenario(mut self, scenario: ScenarioSpec) -> Self {
        self.scenario = scenario;
        self
    }
}

/// Outcome of one trial.
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    pub spec: TrialSpec,
    pub tuned_theta: Vec<f64>,
    /// Mean / stddev execution time at the tuned configuration (5 noisy
    /// runs on the simulator).
    pub tuned_mean_s: f64,
    pub tuned_std_s: f64,
    /// Same for the default configuration.
    pub default_mean_s: f64,
    /// Live-system observations consumed while tuning.
    pub observations: u64,
    /// What-if model evaluations (model-based tuners only).
    pub model_evals: u64,
    /// Simulated profiling overhead (Starfish/PPABS; 0 for SPSA).
    pub profiling_overhead_s: f64,
    /// Tuner wall-clock on this machine.
    pub tuning_wall_ms: f64,
    /// SPSA per-iteration history (empty for other algorithms).
    pub history: Vec<IterRecord>,
}

impl TrialOutcome {
    /// The paper's headline metric: % decrease vs. the default config.
    pub fn pct_decrease(&self) -> f64 {
        100.0 * (self.default_mean_s - self.tuned_mean_s) / self.default_mean_s
    }
}

/// Measurement error of a single-shot job profile (lognormal sigma applied
/// to each data-flow feature). Profiling-based tuners see the workload
/// through this lens; SPSA never needs a profile.
pub const PROFILE_NOISE_SIGMA: f64 = 0.35;

/// Build the workload profile for a benchmark by really running it on
/// sampled data. Profiles are cached per (benchmark, seed): the engine run
/// costs ~150 ms and campaigns request the same profile for every trial
/// (§Perf optimization 1 — see EXPERIMENTS.md).
pub fn profile_for(benchmark: Benchmark, seed: u64) -> WorkloadProfile {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<HashMap<(Benchmark, u64), WorkloadProfile>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(p) = cache.lock().unwrap().get(&(benchmark, seed)) {
        return p.clone();
    }
    let mut rng = Rng::seeded(seed);
    let p = benchmark.paper_profile(&mut rng);
    cache.lock().unwrap().insert((benchmark, seed), p.clone());
    p
}

/// Evaluate a θ on the simulator with `n` noisy runs under `scenario`;
/// returns (mean, std). The runs are independent verification jobs, so
/// they fan across the worker pool (`HSPSA_WORKERS` knob); per-run seeds
/// are fixed up front, so the statistics are identical at any worker
/// count. Failed runs (max.attempts exhausted) carry the objective-layer
/// penalty so robustness tables surface them.
pub fn evaluate_theta(
    space: &ParameterSpace,
    cluster: &ClusterSpec,
    w: &WorkloadProfile,
    theta: &[f64],
    n: u64,
    seed: u64,
    scenario: &ScenarioSpec,
) -> (f64, f64) {
    let cfg = space.materialize(theta);
    let jobs: Vec<SimJob> = (0..n)
        .map(|i| SimJob {
            config: cfg.clone(),
            opts: SimOptions { seed: seed ^ (i + 1), noise: true, scenario: scenario.clone() },
        })
        .collect();
    let runs: Vec<f64> = simulate_batch_auto(cluster, jobs, w)
        .iter()
        .map(|r| crate::tuner::Metric::ExecTime.score(r))
        .collect();
    (mean(&runs), stddev(&runs))
}

/// Run one tuning trial end to end.
pub fn run_trial(spec: &TrialSpec) -> TrialOutcome {
    let space = ParameterSpace::for_version(spec.version);
    let cluster = ClusterSpec::paper_cluster();
    // fixed profiling seed: all algorithms tune the *same* workload
    let w = profile_for(spec.benchmark, 1000);
    let features = ClusterFeatures::from_spec(&cluster, spec.version);
    let t0 = std::time::Instant::now();

    let mut observations = 0;
    let mut model_evals = 0;
    let mut profiling_overhead_s = 0.0;
    let mut history = Vec::new();

    let tuned_theta = match spec.algo {
        Algo::Default => space.default_theta(),
        Algo::Spsa => {
            let mut obj =
                SimObjective::new(space.clone(), cluster.clone(), w.clone(), spec.seed)
                    .with_scenario(spec.scenario.clone());
            let spsa = Spsa::for_space(
                SpsaConfig { max_iters: spec.iters, seed: spec.seed, ..Default::default() },
                &space,
            );
            let res = spsa.run(&mut obj, space.default_theta());
            observations = res.observations;
            history = res.history;
            // Deploy the best configuration observed during learning: the
            // coordinator has every iterate's measured time at hand, and
            // the final iterate still carries the last noisy step.
            res.best_theta
        }
        Algo::SpsaSurrogate => {
            // surrogate SPSA: iterate on the analytic model only, then
            // deploy. Uses the rust what-if here; the artifact-backed
            // variant lives in examples/whatif_engine.rs. The model is
            // driven through the same CostEvaluator batching trait the
            // CBO baselines use (CostObjective bridge).
            let mut evaluator = RustWhatIf::new(space.clone(), w.clone(), features.clone());
            let spsa = Spsa::for_space(
                SpsaConfig { max_iters: spec.iters * 4, seed: spec.seed, ..Default::default() },
                &space,
            );
            let mut obj = CostObjective::new(&mut evaluator);
            let res = spsa.run(&mut obj, space.default_theta());
            model_evals = res.observations;
            res.best_theta
        }
        Algo::Starfish => {
            // Starfish characterizes the job from ONE instrumented run: its
            // what-if engine sees a single-shot noisy profile (§6.8 pt 4).
            let mut prof_rng = Rng::seeded(spec.seed ^ 0x5F15);
            let noisy_w = w.with_measurement_noise(&mut prof_rng, PROFILE_NOISE_SIGMA);
            let mut evaluator = RustWhatIf::new(space.clone(), noisy_w, features.clone());
            let res = starfish_tune(
                &space,
                &cluster,
                &w,
                &mut evaluator,
                &RrsConfig { seed: spec.seed, ..Default::default() },
                spec.seed,
            );
            model_evals = res.model_evals;
            profiling_overhead_s = res.profiling_overhead_s;
            observations = 1; // the single profiled run
            res.best_theta
        }
        Algo::Ppabs => {
            // PPABS likewise profiles each corpus job once.
            let mut prof_rng = Rng::seeded(spec.seed ^ 0x99AB);
            let corpus: Vec<WorkloadProfile> = training_corpus(2000)
                .iter()
                .map(|c| c.with_measurement_noise(&mut prof_rng, PROFILE_NOISE_SIGMA))
                .collect();
            let ppabs = Ppabs::train(&space, &cluster, &corpus, 4, spec.seed);
            model_evals = ppabs.model_evals;
            profiling_overhead_s = ppabs.profiling_overhead_s;
            observations = corpus.len() as u64;
            ppabs.configure(&w)
        }
        Algo::HillClimb => {
            let mut obj =
                SimObjective::new(space.clone(), cluster.clone(), w.clone(), spec.seed)
                    .with_scenario(spec.scenario.clone());
            let res = hill_climb(
                &mut obj,
                space.default_theta(),
                &HillClimbConfig { budget: spec.iters * 2, seed: spec.seed, ..Default::default() },
            );
            observations = res.observations;
            res.best_theta
        }
        Algo::Random => {
            let mut obj =
                SimObjective::new(space.clone(), cluster.clone(), w.clone(), spec.seed)
                    .with_scenario(spec.scenario.clone());
            let res =
                random_search(&mut obj, space.default_theta(), spec.iters * 2, spec.seed);
            observations = res.observations;
            res.best_theta
        }
    };
    let tuning_wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    const EVAL_SEED: u64 = 0xE7A1;
    let (tuned_mean_s, tuned_std_s) = evaluate_theta(
        &space,
        &cluster,
        &w,
        &tuned_theta,
        5,
        spec.seed ^ EVAL_SEED,
        &spec.scenario,
    );
    let (default_mean_s, _) = evaluate_theta(
        &space,
        &cluster,
        &w,
        &space.default_theta(),
        5,
        spec.seed ^ EVAL_SEED,
        &spec.scenario,
    );

    TrialOutcome {
        spec: spec.clone(),
        tuned_theta,
        tuned_mean_s,
        tuned_std_s,
        default_mean_s,
        observations,
        model_evals,
        profiling_overhead_s,
        tuning_wall_ms,
        history,
    }
}

/// Run many trials across the worker pool (leader/worker topology).
/// Worker count honors `HSPSA_WORKERS` (1 = fully sequential).
pub fn run_campaign(specs: Vec<TrialSpec>) -> Vec<TrialOutcome> {
    let jobs: Vec<Box<dyn FnOnce() -> TrialOutcome + Send>> = specs
        .into_iter()
        .map(|s| Box::new(move || run_trial(&s)) as _)
        .collect();
    run_parallel(jobs, resolve_workers(None))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spsa_trial_beats_default() {
        let spec = TrialSpec::new(Benchmark::Terasort, HadoopVersion::V1, Algo::Spsa, 5);
        let out = run_trial(&spec);
        assert!(out.pct_decrease() > 30.0, "decrease {:.1}%", out.pct_decrease());
        assert_eq!(out.history.len() as u64, out.spec.iters);
        assert!(out.observations >= 2 * out.spec.iters);
        assert_eq!(out.profiling_overhead_s, 0.0);
    }

    #[test]
    fn default_trial_is_identity() {
        let spec = TrialSpec::new(Benchmark::Grep, HadoopVersion::V2, Algo::Default, 1);
        let out = run_trial(&spec);
        assert!((out.pct_decrease()).abs() < 1e-9);
        assert_eq!(out.observations, 0);
    }

    #[test]
    fn campaign_runs_parallel_trials() {
        let specs = vec![
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Spsa, 1),
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Random, 1),
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Default, 1),
        ];
        let out = run_campaign(specs);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].spec.algo, Algo::Spsa);
        assert_eq!(out[2].spec.algo, Algo::Default);
        // both live-system tuners improve on the default for bigram
        assert!(out[0].pct_decrease() > 20.0, "spsa {:.1}%", out[0].pct_decrease());
        assert!(out[1].pct_decrease() > 0.0, "random {:.1}%", out[1].pct_decrease());
    }

    #[test]
    fn scenario_trial_tunes_under_faults() {
        // SPSA observing a faulty heterogeneous cluster must still beat the
        // default configuration evaluated under the same scenario.
        let scenario = ScenarioSpec::default()
            .with_failures(0.05)
            .with_max_attempts(10)
            .with_slow_node(2, 0.6)
            .with_slow_node(5, 0.7)
            .with_speculation(true);
        let spec = TrialSpec::new(Benchmark::Terasort, HadoopVersion::V1, Algo::Spsa, 5)
            .with_scenario(scenario);
        let out = run_trial(&spec);
        assert!(
            out.pct_decrease() > 20.0,
            "under faults only {:.1}% decrease",
            out.pct_decrease()
        );
    }

    #[test]
    fn starfish_trial_reports_overheads() {
        let spec = TrialSpec::new(Benchmark::InvertedIndex, HadoopVersion::V1, Algo::Starfish, 2);
        let out = run_trial(&spec);
        assert!(out.profiling_overhead_s > 0.0);
        assert!(out.model_evals > 100);
        assert!(out.pct_decrease() > 0.0);
    }
}
