//! Tuning campaigns: the orchestration layer that runs a tuner against a
//! benchmark on the simulated cluster and evaluates the outcome — the
//! equivalent of the SPSA process the paper runs on the NameNode (§6),
//! generalized over the comparison algorithms of §6.6.
//!
//! Every algorithm is a [`Tuner`](crate::tuner::Tuner) resolved from the
//! registry and driven through one budget-metered
//! [`EvalBroker`](crate::tuner::EvalBroker): identical observation budgets,
//! identical accounting, one convergence trace — the bespoke per-algorithm
//! dispatch this module used to carry is gone.

use crate::cluster::ClusterSpec;
use crate::config::{HadoopVersion, ParameterSpace};
use crate::sim::{simulate_batch_auto, ScenarioSpec, SimJob, SimOptions};
use crate::tuner::registry::{self, TunerContext};
use crate::tuner::{Budget, EvalBroker, EvalRecord, IterRecord, SimObjective};
use crate::util::rng::Rng;
use crate::util::stats::{mean, stddev};
use crate::workloads::{Benchmark, WorkloadProfile};

use super::pool::{resolve_workers, run_parallel};

// compat re-export: the constant moved to the registry with the tuners
pub use crate::tuner::registry::PROFILE_NOISE_SIGMA;

/// Tuning algorithm under test — a thin, enum-typed shim over the tuner
/// registry (experiment code matches on it; the registry owns behavior).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// No tuning: Hadoop defaults (the paper's baseline row).
    Default,
    /// The paper's contribution (Algorithm 1).
    Spsa,
    /// SPSA on the AOT surrogate model instead of the live system
    /// (extension; runs through the PJRT artifact when available).
    SpsaSurrogate,
    /// Starfish: profile + what-if (analytic model) + RRS.
    Starfish,
    /// PPABS: signature clustering + SA on a reduced space.
    Ppabs,
    /// MROnline-style hill climbing on the live system.
    HillClimb,
    /// Random search on the live system (ablation anchor).
    Random,
    /// Random-direction SA — the paper §7 noisy-gradient sibling.
    Rdsa,
    /// Nelder–Mead downhill simplex on the live system.
    NelderMead,
    /// TPE-style Bayesian optimization over the broker trace.
    Tpe,
}

impl Algo {
    pub fn all() -> [Algo; 10] {
        [
            Algo::Default,
            Algo::Spsa,
            Algo::SpsaSurrogate,
            Algo::Starfish,
            Algo::Ppabs,
            Algo::HillClimb,
            Algo::Random,
            Algo::Rdsa,
            Algo::NelderMead,
            Algo::Tpe,
        ]
    }

    /// Canonical registry name ([`crate::tuner::registry::find`]).
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Default => "default",
            Algo::Spsa => "spsa",
            Algo::SpsaSurrogate => "spsa-surrogate",
            Algo::Starfish => "starfish",
            Algo::Ppabs => "ppabs",
            Algo::HillClimb => "hillclimb",
            Algo::Random => "random",
            Algo::Rdsa => "rdsa",
            Algo::NelderMead => "nelder-mead",
            Algo::Tpe => "tpe",
        }
    }

    /// Display label (every output of this round-trips through
    /// [`Algo::from_name`], case-insensitively).
    pub fn label(&self) -> &'static str {
        match self {
            Algo::Default => "Default",
            Algo::Spsa => "SPSA",
            Algo::SpsaSurrogate => "SPSA-surrogate",
            Algo::Starfish => "Starfish",
            Algo::Ppabs => "PPABS",
            Algo::HillClimb => "HillClimb",
            Algo::Random => "Random",
            Algo::Rdsa => "RDSA",
            Algo::NelderMead => "NelderMead",
            Algo::Tpe => "TPE",
        }
    }

    /// Resolve through the registry: trims, matches canonical names,
    /// aliases and labels case-insensitively.
    pub fn from_name(s: &str) -> Option<Algo> {
        let entry = registry::find(s)?;
        Algo::all().into_iter().find(|a| a.name() == entry.name)
    }
}

/// One tuning trial: algorithm × benchmark × Hadoop version × seed, under
/// one shared live-observation budget.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    pub benchmark: Benchmark,
    pub version: HadoopVersion,
    pub algo: Algo,
    pub seed: u64,
    /// Live-observation budget the tuner may spend — the same number for
    /// every algorithm of a comparison, so best-found-vs-budget is the
    /// native currency (the paper's 2-obs/iter economy claim, §6.6).
    pub budget: Budget,
    /// Execution-substrate regime: live-system tuners observe the system
    /// under it, and the tuned/default verification runs execute under it
    /// too. Benign by default.
    pub scenario: ScenarioSpec,
}

/// Default per-trial budget: 90 observations ≈ 30 SPSA iterations of the
/// paper's estimator with gradient averaging (3 obs each).
pub const DEFAULT_TRIAL_BUDGET: u64 = 90;

impl TrialSpec {
    pub fn new(benchmark: Benchmark, version: HadoopVersion, algo: Algo, seed: u64) -> Self {
        TrialSpec {
            benchmark,
            version,
            algo,
            seed,
            budget: Budget::obs(DEFAULT_TRIAL_BUDGET),
            scenario: ScenarioSpec::default(),
        }
    }

    /// Builder: run this trial under a fault/heterogeneity scenario.
    pub fn with_scenario(mut self, scenario: ScenarioSpec) -> Self {
        self.scenario = scenario;
        self
    }

    /// Builder: cap the live-observation budget.
    pub fn with_budget(mut self, budget: Budget) -> Self {
        self.budget = budget;
        self
    }
}

/// Outcome of one trial.
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    pub spec: TrialSpec,
    pub tuned_theta: Vec<f64>,
    /// Mean / stddev execution time at the tuned configuration (5 noisy
    /// runs on the simulator).
    pub tuned_mean_s: f64,
    pub tuned_std_s: f64,
    /// Same for the default configuration.
    pub default_mean_s: f64,
    /// Live-system observations consumed while tuning (broker-metered;
    /// always ≤ `spec.budget.max_obs`).
    pub observations: u64,
    /// What-if model evaluations (model-based tuners only).
    pub model_evals: u64,
    /// Simulated profiling overhead (Starfish/PPABS; 0 for SPSA).
    pub profiling_overhead_s: f64,
    /// Tuner wall-clock on this machine.
    pub tuning_wall_ms: f64,
    /// SPSA per-iteration history (empty for other algorithms).
    pub history: Vec<IterRecord>,
    /// The broker's uniform convergence trace — every observation served
    /// through the broker, in order. Empty for model-only tuners, and for
    /// PPABS, whose corpus profiling is metered via `EvalBroker::charge`
    /// (runs of *other* workloads never enter this trial's trace).
    pub eval_trace: Vec<EvalRecord>,
}

impl TrialOutcome {
    /// The paper's headline metric: % decrease vs. the default config.
    pub fn pct_decrease(&self) -> f64 {
        100.0 * (self.default_mean_s - self.tuned_mean_s) / self.default_mean_s
    }
}

/// Build the workload profile for a benchmark by really running it on
/// sampled data. Profiles are cached per (benchmark, seed): the engine run
/// costs ~150 ms and campaigns request the same profile for every trial
/// (§Perf optimization 1 — see EXPERIMENTS.md).
pub fn profile_for(benchmark: Benchmark, seed: u64) -> WorkloadProfile {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<HashMap<(Benchmark, u64), WorkloadProfile>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(p) = cache.lock().unwrap().get(&(benchmark, seed)) {
        return p.clone();
    }
    let mut rng = Rng::seeded(seed);
    let p = benchmark.paper_profile(&mut rng);
    cache.lock().unwrap().insert((benchmark, seed), p.clone());
    p
}

/// Evaluate a θ on the simulator with `n` noisy runs under `scenario`;
/// returns (mean, std). The runs are independent verification jobs, so
/// they fan across the worker pool (`HSPSA_WORKERS` knob); per-run seeds
/// are fixed up front, so the statistics are identical at any worker
/// count. Failed runs (max.attempts exhausted) carry the objective-layer
/// penalty so robustness tables surface them.
pub fn evaluate_theta(
    space: &ParameterSpace,
    cluster: &ClusterSpec,
    w: &WorkloadProfile,
    theta: &[f64],
    n: u64,
    seed: u64,
    scenario: &ScenarioSpec,
) -> (f64, f64) {
    let cfg = space.materialize(theta);
    let jobs: Vec<SimJob> = (0..n)
        .map(|i| SimJob {
            config: cfg.clone(),
            opts: SimOptions { seed: seed ^ (i + 1), noise: true, scenario: scenario.clone() },
        })
        .collect();
    let runs: Vec<f64> = simulate_batch_auto(cluster, jobs, w)
        .iter()
        .map(|r| crate::tuner::Metric::ExecTime.score(r))
        .collect();
    (mean(&runs), stddev(&runs))
}

/// Run one tuning trial end to end: resolve the algorithm from the
/// registry, let it spend the trial's budget through a metered broker,
/// then verify tuned vs default on the simulator.
pub fn run_trial(spec: &TrialSpec) -> TrialOutcome {
    let space = ParameterSpace::for_version(spec.version);
    let cluster = ClusterSpec::paper_cluster();
    // fixed profiling seed: all algorithms tune the *same* workload
    let w = profile_for(spec.benchmark, 1000);
    let ctx = TunerContext {
        version: spec.version,
        cluster: cluster.clone(),
        workload: w.clone(),
    };
    let tuner = registry::create(spec.algo.name(), &ctx)
        .expect("every Algo maps to a registry entry");

    let t0 = std::time::Instant::now();
    let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), spec.seed)
        .with_scenario(spec.scenario.clone());
    let mut broker =
        EvalBroker::new(&mut obj, spec.budget).with_cache(tuner.cache_policy());
    let out = tuner.tune(&mut broker, &space, spec.seed);
    let observations = broker.evals_used();
    let eval_trace = broker.take_trace();
    let tuning_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(
        observations <= spec.budget.max_obs,
        "{} overspent its budget: {observations} > {}",
        spec.algo.label(),
        spec.budget.max_obs
    );

    const EVAL_SEED: u64 = 0xE7A1;
    let (tuned_mean_s, tuned_std_s) = evaluate_theta(
        &space,
        &cluster,
        &w,
        &out.best_theta,
        5,
        spec.seed ^ EVAL_SEED,
        &spec.scenario,
    );
    let (default_mean_s, _) = evaluate_theta(
        &space,
        &cluster,
        &w,
        &space.default_theta(),
        5,
        spec.seed ^ EVAL_SEED,
        &spec.scenario,
    );

    TrialOutcome {
        spec: spec.clone(),
        tuned_theta: out.best_theta,
        tuned_mean_s,
        tuned_std_s,
        default_mean_s,
        observations,
        model_evals: out.model_evals,
        profiling_overhead_s: out.profiling_overhead_s,
        tuning_wall_ms,
        history: out.history,
        eval_trace,
    }
}

/// Run many trials across the worker pool (leader/worker topology).
/// Worker count honors `HSPSA_WORKERS` (1 = fully sequential).
pub fn run_campaign(specs: Vec<TrialSpec>) -> Vec<TrialOutcome> {
    let jobs: Vec<Box<dyn FnOnce() -> TrialOutcome + Send>> = specs
        .into_iter()
        .map(|s| Box::new(move || run_trial(&s)) as _)
        .collect();
    run_parallel(jobs, resolve_workers(None))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_label_round_trips_case_insensitively() {
        for algo in Algo::all() {
            assert_eq!(Algo::from_name(algo.label()), Some(algo), "{}", algo.label());
            assert_eq!(
                Algo::from_name(&algo.label().to_uppercase()),
                Some(algo),
                "uppercased {}",
                algo.label()
            );
            assert_eq!(Algo::from_name(&format!("  {} ", algo.name())), Some(algo));
        }
        // legacy aliases stay accepted
        assert_eq!(Algo::from_name("hill"), Some(Algo::HillClimb));
        assert_eq!(Algo::from_name("mronline"), Some(Algo::HillClimb));
        assert_eq!(Algo::from_name("surrogate"), Some(Algo::SpsaSurrogate));
        assert_eq!(Algo::from_name("simplex"), Some(Algo::NelderMead));
        assert_eq!(Algo::from_name("bayesopt"), Some(Algo::Tpe));
        assert_eq!(Algo::from_name("rd-sa"), Some(Algo::Rdsa));
        assert_eq!(Algo::from_name("bogus"), None);
    }

    #[test]
    fn spsa_trial_beats_default() {
        let spec = TrialSpec::new(Benchmark::Terasort, HadoopVersion::V1, Algo::Spsa, 5);
        let out = run_trial(&spec);
        assert!(out.pct_decrease() > 30.0, "decrease {:.1}%", out.pct_decrease());
        // 3 obs per iteration, whole iterations only, within budget
        assert_eq!(out.history.len() as u64 * 3, out.observations);
        assert!(out.observations <= out.spec.budget.max_obs);
        assert!(out.observations >= out.spec.budget.max_obs / 2, "barely tuned");
        assert_eq!(out.profiling_overhead_s, 0.0);
        // the uniform trace mirrors the broker accounting
        assert_eq!(out.eval_trace.len() as u64, out.observations);
    }

    #[test]
    fn default_trial_is_identity() {
        let spec = TrialSpec::new(Benchmark::Grep, HadoopVersion::V2, Algo::Default, 1);
        let out = run_trial(&spec);
        assert!((out.pct_decrease()).abs() < 1e-9);
        assert_eq!(out.observations, 0);
        assert!(out.eval_trace.is_empty());
    }

    #[test]
    fn campaign_runs_parallel_trials() {
        let specs = vec![
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Spsa, 1),
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Random, 1),
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Default, 1),
        ];
        let out = run_campaign(specs);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].spec.algo, Algo::Spsa);
        assert_eq!(out[2].spec.algo, Algo::Default);
        // both live-system tuners improve on the default for bigram
        assert!(out[0].pct_decrease() > 20.0, "spsa {:.1}%", out[0].pct_decrease());
        assert!(out[1].pct_decrease() > 0.0, "random {:.1}%", out[1].pct_decrease());
        // random search spends the whole shared budget, to the observation
        assert_eq!(out[1].observations, out[1].spec.budget.max_obs);
    }

    #[test]
    fn scenario_trial_tunes_under_faults() {
        // SPSA observing a faulty heterogeneous cluster must still beat the
        // default configuration evaluated under the same scenario.
        let scenario = ScenarioSpec::default()
            .with_failures(0.05)
            .with_max_attempts(10)
            .with_slow_node(2, 0.6)
            .with_slow_node(5, 0.7)
            .with_speculation(true);
        let spec = TrialSpec::new(Benchmark::Terasort, HadoopVersion::V1, Algo::Spsa, 5)
            .with_scenario(scenario);
        let out = run_trial(&spec);
        assert!(
            out.pct_decrease() > 20.0,
            "under faults only {:.1}% decrease",
            out.pct_decrease()
        );
    }

    #[test]
    fn starfish_trial_reports_overheads() {
        let spec = TrialSpec::new(Benchmark::InvertedIndex, HadoopVersion::V1, Algo::Starfish, 2);
        let out = run_trial(&spec);
        assert!(out.profiling_overhead_s > 0.0);
        assert!(out.model_evals > 100);
        assert!(out.pct_decrease() > 0.0);
        assert_eq!(out.observations, 1, "starfish profiles exactly once");
    }

    #[test]
    fn every_algo_runs_under_one_small_budget() {
        // The whole registry through run_trial at a tight shared budget:
        // nothing overspends (run_trial asserts) and outcomes are sane.
        for algo in Algo::all() {
            let spec = TrialSpec::new(Benchmark::Grep, HadoopVersion::V1, algo, 3)
                .with_budget(Budget::obs(24));
            let out = run_trial(&spec);
            assert!(out.observations <= 24, "{}", algo.label());
            assert!(out.tuned_mean_s > 0.0, "{}", algo.label());
        }
    }
}
