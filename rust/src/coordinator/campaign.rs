//! Tuning campaigns: the orchestration layer that runs a tuner against a
//! benchmark on the simulated cluster and evaluates the outcome — the
//! equivalent of the SPSA process the paper runs on the NameNode (§6),
//! generalized over the comparison algorithms of §6.6.
//!
//! Every algorithm is a [`Tuner`](crate::tuner::Tuner) resolved from the
//! registry and driven through one budget-metered
//! [`EvalBroker`](crate::tuner::EvalBroker): identical observation budgets,
//! identical accounting, one convergence trace — the bespoke per-algorithm
//! dispatch this module used to carry is gone.

use crate::cluster::ClusterSpec;
use crate::config::{HadoopVersion, ParameterSpace};
use crate::sim::{simulate_batch_auto, ScenarioSpec, SimJob, SimOptions};
use crate::tuner::registry::{self, TunerContext};
use crate::tuner::{
    Budget, CachePolicy, EvalBroker, EvalRecord, FrozenObjective, IterRecord, Objective,
    SimObjective,
};
use crate::util::rng::Rng;
use crate::util::stats::{mean, stddev};
use crate::workloads::{Benchmark, WorkloadProfile};

use super::pool::{resolve_workers, run_parallel};

// compat re-export: the constant moved to the registry with the tuners
pub use crate::tuner::registry::PROFILE_NOISE_SIGMA;

/// Tuning algorithm under test — a thin, enum-typed shim over the tuner
/// registry (experiment code matches on it; the registry owns behavior).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// No tuning: Hadoop defaults (the paper's baseline row).
    Default,
    /// The paper's contribution (Algorithm 1).
    Spsa,
    /// SPSA on the AOT surrogate model instead of the live system
    /// (extension; runs through the PJRT artifact when available).
    SpsaSurrogate,
    /// Starfish: profile + what-if (analytic model) + RRS.
    Starfish,
    /// PPABS: signature clustering + SA on a reduced space.
    Ppabs,
    /// MROnline-style hill climbing on the live system.
    HillClimb,
    /// Random search on the live system (ablation anchor).
    Random,
    /// Random-direction SA — the paper §7 noisy-gradient sibling.
    Rdsa,
    /// Nelder–Mead downhill simplex on the live system.
    NelderMead,
    /// TPE-style Bayesian optimization over the broker trace.
    Tpe,
}

impl Algo {
    pub fn all() -> [Algo; 10] {
        [
            Algo::Default,
            Algo::Spsa,
            Algo::SpsaSurrogate,
            Algo::Starfish,
            Algo::Ppabs,
            Algo::HillClimb,
            Algo::Random,
            Algo::Rdsa,
            Algo::NelderMead,
            Algo::Tpe,
        ]
    }

    /// Canonical registry name ([`crate::tuner::registry::find`]).
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Default => "default",
            Algo::Spsa => "spsa",
            Algo::SpsaSurrogate => "spsa-surrogate",
            Algo::Starfish => "starfish",
            Algo::Ppabs => "ppabs",
            Algo::HillClimb => "hillclimb",
            Algo::Random => "random",
            Algo::Rdsa => "rdsa",
            Algo::NelderMead => "nelder-mead",
            Algo::Tpe => "tpe",
        }
    }

    /// Display label (every output of this round-trips through
    /// [`Algo::from_name`], case-insensitively).
    pub fn label(&self) -> &'static str {
        match self {
            Algo::Default => "Default",
            Algo::Spsa => "SPSA",
            Algo::SpsaSurrogate => "SPSA-surrogate",
            Algo::Starfish => "Starfish",
            Algo::Ppabs => "PPABS",
            Algo::HillClimb => "HillClimb",
            Algo::Random => "Random",
            Algo::Rdsa => "RDSA",
            Algo::NelderMead => "NelderMead",
            Algo::Tpe => "TPE",
        }
    }

    /// Resolve through the registry: trims, matches canonical names,
    /// aliases and labels case-insensitively.
    pub fn from_name(s: &str) -> Option<Algo> {
        let entry = registry::find(s)?;
        Algo::all().into_iter().find(|a| a.name() == entry.name)
    }
}

/// One tuning trial: algorithm × benchmark × Hadoop version × seed, under
/// one shared live-observation budget.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    pub benchmark: Benchmark,
    pub version: HadoopVersion,
    pub algo: Algo,
    pub seed: u64,
    /// Live-observation budget the tuner may spend — the same number for
    /// every algorithm of a comparison, so best-found-vs-budget is the
    /// native currency (the paper's 2-obs/iter economy claim, §6.6).
    pub budget: Budget,
    /// Execution-substrate regime: live-system tuners observe the system
    /// under it, and the tuned/default verification runs execute under it
    /// too. Benign by default.
    pub scenario: ScenarioSpec,
}

/// Default per-trial budget: 90 observations ≈ 30 SPSA iterations of the
/// paper's estimator with gradient averaging (3 obs each).
pub const DEFAULT_TRIAL_BUDGET: u64 = 90;

impl TrialSpec {
    pub fn new(benchmark: Benchmark, version: HadoopVersion, algo: Algo, seed: u64) -> Self {
        TrialSpec {
            benchmark,
            version,
            algo,
            seed,
            budget: Budget::obs(DEFAULT_TRIAL_BUDGET),
            scenario: ScenarioSpec::default(),
        }
    }

    /// Builder: run this trial under a fault/heterogeneity scenario.
    pub fn with_scenario(mut self, scenario: ScenarioSpec) -> Self {
        self.scenario = scenario;
        self
    }

    /// Builder: cap the live-observation budget.
    pub fn with_budget(mut self, budget: Budget) -> Self {
        self.budget = budget;
        self
    }
}

/// Outcome of one trial.
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    pub spec: TrialSpec,
    pub tuned_theta: Vec<f64>,
    /// Mean / stddev execution time at the tuned configuration (5 noisy
    /// runs on the simulator).
    pub tuned_mean_s: f64,
    pub tuned_std_s: f64,
    /// Same for the default configuration.
    pub default_mean_s: f64,
    /// Live-system observations consumed while tuning (broker-metered;
    /// always ≤ `spec.budget.max_obs`).
    pub observations: u64,
    /// What-if model evaluations (model-based tuners only).
    pub model_evals: u64,
    /// Simulated profiling overhead (Starfish/PPABS; 0 for SPSA).
    pub profiling_overhead_s: f64,
    /// Tuner wall-clock on this machine.
    pub tuning_wall_ms: f64,
    /// Modeled wall-clock the tuning run cost, in simulated seconds
    /// (per-wave max-duration + dispatch overhead, plus charged external
    /// profiling — the broker's [`elapsed_model_time`]).
    ///
    /// [`elapsed_model_time`]: crate::tuner::EvalBroker::elapsed_model_time
    pub elapsed_model_s: f64,
    /// SPSA per-iteration history (empty for other algorithms).
    pub history: Vec<IterRecord>,
    /// The broker's uniform convergence trace — every observation served
    /// through the broker, in order. Empty for model-only tuners, and for
    /// PPABS, whose corpus profiling is metered via `EvalBroker::charge`
    /// (runs of *other* workloads never enter this trial's trace).
    pub eval_trace: Vec<EvalRecord>,
    /// `true` when the deployed `tuned_theta`'s claimed f replays a
    /// store-served value from an earlier campaign that no live
    /// observation of this run matched or beat — the deployment is
    /// noise-frozen (see [`ObsSource::Store`]). Always `false` for cold
    /// (service-less) trials.
    ///
    /// [`ObsSource::Store`]: crate::tuner::ObsSource
    pub noise_frozen: bool,
    /// Observations served free by the cross-campaign store (warm-start
    /// seeds + store-tier lookup hits). 0 for cold trials.
    pub store_hits: u64,
}

impl TrialOutcome {
    /// The paper's headline metric: % decrease vs. the default config.
    pub fn pct_decrease(&self) -> f64 {
        100.0 * (self.default_mean_s - self.tuned_mean_s) / self.default_mean_s
    }
}

/// Build the workload profile for a benchmark by really running it on
/// sampled data. Profiles are cached per (benchmark, seed): the engine run
/// costs ~150 ms and campaigns request the same profile for every trial
/// (§Perf optimization 1 — see EXPERIMENTS.md).
pub fn profile_for(benchmark: Benchmark, seed: u64) -> WorkloadProfile {
    use std::collections::BTreeMap;
    use std::sync::{Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<BTreeMap<(Benchmark, u64), WorkloadProfile>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    if let Some(p) = cache.lock().unwrap().get(&(benchmark, seed)) {
        return p.clone();
    }
    let mut rng = Rng::seeded(seed);
    let p = benchmark.paper_profile(&mut rng);
    cache.lock().unwrap().insert((benchmark, seed), p.clone());
    p
}

/// Evaluate a θ on the simulator with `n` noisy runs under `scenario`;
/// returns (mean, std). The runs are independent verification jobs, so
/// they fan across the worker pool (`HSPSA_WORKERS` knob); per-run seeds
/// are fixed up front, so the statistics are identical at any worker
/// count. Failed runs (max.attempts exhausted) carry the objective-layer
/// penalty so robustness tables surface them.
pub fn evaluate_theta(
    space: &ParameterSpace,
    cluster: &ClusterSpec,
    w: &WorkloadProfile,
    theta: &[f64],
    n: u64,
    seed: u64,
    scenario: &ScenarioSpec,
) -> (f64, f64) {
    let cfg = space.materialize(theta);
    let jobs: Vec<SimJob> = (0..n)
        .map(|i| SimJob {
            config: cfg.clone(),
            opts: SimOptions { seed: seed ^ (i + 1), noise: true, scenario: scenario.clone() },
        })
        .collect();
    let runs: Vec<f64> = simulate_batch_auto(cluster, jobs, w)
        .iter()
        .map(|r| crate::tuner::Metric::ExecTime.score(r))
        .collect();
    (mean(&runs), stddev(&runs))
}

/// Cross-campaign warm-start context for one trial — assembled by the
/// service layer ([`coordinator::service`]) from the observation store's
/// records for campaigns whose workload fingerprint matched this request.
///
/// [`coordinator::service`]: crate::coordinator::service
#[derive(Clone, Debug)]
pub struct WarmStart {
    /// Prior observations `(full-dimensional θ, f)`, noise-frozen at
    /// their original draw. Ingested into the broker as free
    /// [`ObsSource::Store`] records (and, for `Quantized`-policy tuners,
    /// attached as a store cache tier).
    ///
    /// [`ObsSource::Store`]: crate::tuner::ObsSource
    pub records: Vec<(Vec<f64>, f64)>,
    /// θ-cell size the records' store was keyed under (coarser than the
    /// broker memo's 1e-6, so cross-seed revisits actually hit).
    pub store_quant: f64,
    /// Dimension-pruning mask (Tuneful §3): `true` freezes that
    /// parameter at its default for the whole trial. Empty = no pruning.
    /// Only meaningful for direct-search tuners — model-based tuners
    /// (Starfish, PPABS, surrogate SPSA) need the full space for their
    /// what-if features, and the service never prunes them.
    pub frozen: Vec<bool>,
}

impl WarmStart {
    pub fn new(records: Vec<(Vec<f64>, f64)>, store_quant: f64) -> WarmStart {
        WarmStart { records, store_quant, frozen: Vec::new() }
    }
}

/// Expand a reduced θ (one entry per non-frozen coordinate, in index
/// order) back to the full space: frozen coordinates come from
/// `template`. With an all-false (or empty) mask this is the identity.
pub fn expand_theta(template: &[f64], frozen: &[bool], reduced: &[f64]) -> Vec<f64> {
    if frozen.iter().all(|&fz| !fz) {
        return reduced.to_vec();
    }
    let mut full = template.to_vec();
    let mut j = 0;
    for (i, &fz) in frozen.iter().enumerate() {
        if !fz && j < reduced.len() {
            full[i] = reduced[j];
            j += 1;
        }
    }
    full
}

/// Run one tuning trial end to end: resolve the algorithm from the
/// registry, let it spend the trial's budget through a metered broker,
/// then verify tuned vs default on the simulator.
pub fn run_trial(spec: &TrialSpec) -> TrialOutcome {
    run_trial_warmed(spec, None)
}

/// [`run_trial`], optionally warm-started from a cross-campaign
/// [`WarmStart`]: prior records are served to the tuner for free (store
/// tier + ingested incumbent seeds, both flagged [`ObsSource::Store`]),
/// and a pruning mask shrinks the search space the tuner sees — the
/// objective still evaluates full-dimensional configurations via
/// [`FrozenObjective`], and every θ in the returned outcome/trace is
/// expanded back to the full space. With `warm == None` this is
/// bit-identical to the historical cold path.
///
/// [`ObsSource::Store`]: crate::tuner::ObsSource
/// [`FrozenObjective`]: crate::tuner::FrozenObjective
pub fn run_trial_warmed(spec: &TrialSpec, warm: Option<&WarmStart>) -> TrialOutcome {
    let space = ParameterSpace::for_version(spec.version);
    let cluster = ClusterSpec::paper_cluster();
    // fixed profiling seed: all algorithms tune the *same* workload
    let w = profile_for(spec.benchmark, 1000);
    let ctx = TunerContext {
        version: spec.version,
        cluster: cluster.clone(),
        workload: w.clone(),
    };
    let tuner = registry::create(spec.algo.name(), &ctx)
        .expect("every Algo maps to a registry entry");

    let full_dim = space.dim();
    let template = space.default_theta();
    // honor the pruning mask only when it is well-formed and keeps ≥ 1 dim
    let frozen: Vec<bool> = match warm {
        Some(ws)
            if ws.frozen.len() == full_dim
                && ws.frozen.iter().any(|&fz| fz)
                && !ws.frozen.iter().all(|&fz| fz) =>
        {
            ws.frozen.clone()
        }
        _ => vec![false; full_dim],
    };
    let pruned = frozen.iter().any(|&fz| fz);
    let search_space = if pruned {
        let keep: Vec<bool> = frozen.iter().map(|&fz| !fz).collect();
        space.subspace(&keep)
    } else {
        space.clone()
    };

    // lint:allow(wall-clock): tuning_wall_ms is reporting-only (walltime table) — never feeds modeled results or seeds
    let t0 = std::time::Instant::now();
    let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), spec.seed)
        .with_scenario(spec.scenario.clone());
    // the freeze adapter is an identity layer when nothing is pruned, so
    // cold trials take the exact same code path (and values) as before
    let mut fobj = FrozenObjective::new(&mut obj, template.clone(), &frozen);
    let mut broker =
        EvalBroker::new(&mut fobj, spec.budget).with_cache(tuner.cache_policy());

    if let Some(ws) = warm {
        // project prior full-dim records onto the reduced view: under
        // pruning only records whose frozen coordinates share the
        // template's store cell describe the function the tuner explores
        let quant = if ws.store_quant > 0.0 { ws.store_quant } else { 1e-6 };
        let cell = |x: f64| (x / quant).round() as i64;
        let reduced: Vec<(Vec<f64>, f64)> = ws
            .records
            .iter()
            .filter(|(t, _)| {
                t.len() == full_dim
                    && frozen
                        .iter()
                        .zip(t.iter().zip(&template))
                        .all(|(&fz, (&x, &d))| !fz || cell(x) == cell(d))
            })
            .map(|(t, f)| {
                let r: Vec<f64> = t
                    .iter()
                    .zip(&frozen)
                    .filter(|(_, &fz)| !fz)
                    .map(|(&x, _)| x)
                    .collect();
                (r, *f)
            })
            .collect();
        broker = broker.with_store_tier(quant, &reduced);
        // seed the trace: every prior record replays for free at obs 0,
        // so best-so-far starts at the matched campaigns' incumbent
        for (t, f) in &reduced {
            broker.ingest(t, *f);
        }
    }

    let mut out = tuner.tune(&mut broker, &search_space, spec.seed);
    // Satellite bugfix: a store-served incumbent can beat everything the
    // tuner measured live — deploy the better configuration, but flag it
    // noise-frozen (its f was observed under an earlier campaign's noise
    // stream and never re-verified here).
    if broker.best_noise_frozen() {
        if let Some((bt, bf)) = broker.best() {
            // NaN/∞-safe: replace unless the tuner's claim is already ≤
            if out.best_f.is_nan() || out.best_f > bf {
                out.best_theta = bt.to_vec();
                out.best_f = bf;
                out.noise_frozen = true;
            }
        }
    }
    let noise_frozen = out.noise_frozen;
    let store_hits = broker.store_hits();
    let observations = broker.evals_used();
    let elapsed_model_s = broker.elapsed_model_time();
    let mut eval_trace = broker.take_trace();
    let tuning_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(
        observations <= spec.budget.max_obs,
        "{} overspent its budget: {observations} > {}",
        spec.algo.label(),
        spec.budget.max_obs
    );

    // everything leaving this function is full-dimensional
    let tuned_theta = expand_theta(&template, &frozen, &out.best_theta);
    if pruned {
        for r in &mut eval_trace {
            r.theta = expand_theta(&template, &frozen, &r.theta);
        }
    }

    const EVAL_SEED: u64 = 0xE7A1;
    let (tuned_mean_s, tuned_std_s) = evaluate_theta(
        &space,
        &cluster,
        &w,
        &tuned_theta,
        5,
        spec.seed ^ EVAL_SEED,
        &spec.scenario,
    );
    let (default_mean_s, _) = evaluate_theta(
        &space,
        &cluster,
        &w,
        &space.default_theta(),
        5,
        spec.seed ^ EVAL_SEED,
        &spec.scenario,
    );

    TrialOutcome {
        spec: spec.clone(),
        tuned_theta,
        tuned_mean_s,
        tuned_std_s,
        default_mean_s,
        observations,
        model_evals: out.model_evals,
        profiling_overhead_s: out.profiling_overhead_s,
        tuning_wall_ms,
        elapsed_model_s,
        history: out.history,
        eval_trace,
        noise_frozen,
        store_hits,
    }
}

/// Run many trials across the worker pool (leader/worker topology).
/// Worker count honors `HSPSA_WORKERS` (1 = fully sequential).
pub fn run_campaign(specs: Vec<TrialSpec>) -> Vec<TrialOutcome> {
    let jobs: Vec<Box<dyn FnOnce() -> TrialOutcome + Send>> = specs
        .into_iter()
        .map(|s| Box::new(move || run_trial(&s)) as _)
        .collect();
    run_parallel(jobs, resolve_workers(None))
}

// ---------------------------------------------------------------------------
// campaign scheduler: one shared wall-clock budget across the registry
// ---------------------------------------------------------------------------

/// How a [`CampaignScheduler`] splits its shared wall-clock budget among
/// its tuners.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// Every tuner gets `total / n` modeled seconds up front.
    Equal,
    /// Successive halving: the budget is spent rung by rung; after each
    /// rung the worst half of the survivors (ranked by best *observed* f,
    /// ties broken by registry order) is culled, and the culled tuners'
    /// **unspent** allocation flows back into the pool the remaining
    /// rungs share — reinvested in the survivors.
    SuccessiveHalving,
    /// Hyperband-style bracketed halving: the budget splits equally over
    /// `min(3, ⌈log₂ n⌉)` brackets; each bracket runs a full halving
    /// schedule, and every non-terminal tuner — including tuners culled in
    /// an earlier bracket — is revived at the next bracket and *extended*
    /// from its checkpoint, so an early aggressive cull is a deferral, not
    /// a death sentence. Leftover bracket time rolls forward.
    Hyperband,
    /// UCB bandit over tuners: the budget is cut into fixed slices
    /// (4 per tuner); each slice goes to the tuner maximizing
    /// `mean-reward / max-mean + √(2·ln t / pulls)`, where a pull's reward
    /// is the relative improvement of its best observed f per modeled
    /// second charged. Ties (and the one-pull-each warmup) resolve in
    /// registry order.
    Bandit,
}

impl SchedulerPolicy {
    /// CLI / table name (round-trips through [`SchedulerPolicy::from_name`]).
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerPolicy::Equal => "equal",
            SchedulerPolicy::SuccessiveHalving => "halving",
            SchedulerPolicy::Hyperband => "hyperband",
            SchedulerPolicy::Bandit => "bandit",
        }
    }

    pub fn from_name(s: &str) -> Option<SchedulerPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "equal" => Some(SchedulerPolicy::Equal),
            "halving" | "successive-halving" | "sh" => Some(SchedulerPolicy::SuccessiveHalving),
            "hyperband" | "hb" => Some(SchedulerPolicy::Hyperband),
            "bandit" | "ucb" => Some(SchedulerPolicy::Bandit),
            _ => None,
        }
    }
}

/// What one [`RungEvent`] row records the scheduler doing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RungAction {
    /// First segment of this tuner (fresh start).
    Ran,
    /// Extension resumed from a checkpoint — O(increment) observations.
    Resumed,
    /// Extension by deterministic replay (non-checkpointable tuner); the
    /// replayed prefix is re-simulated but charged zero — only the
    /// increment is billed.
    Replayed,
    /// The tuner's checkpoint channel reported terminal completion (or a
    /// replay made no progress on a larger grant); unspent time reclaimed.
    Finished,
    /// Culled by a halving rung; unspent time reclaimed into the pool.
    Culled,
}

impl RungAction {
    pub fn name(&self) -> &'static str {
        match self {
            RungAction::Ran => "ran",
            RungAction::Resumed => "resumed",
            RungAction::Replayed => "replayed",
            RungAction::Finished => "finished",
            RungAction::Culled => "culled",
        }
    }
}

/// One row of the scheduler's allocation audit trail: every grant,
/// extension, cull and completion, in execution order. This is the table
/// the `scheduler-gauntlet` CI job diffs against its committed fixture.
#[derive(Clone, Debug)]
pub struct RungEvent {
    pub policy: SchedulerPolicy,
    /// Hyperband bracket (0 outside Hyperband).
    pub bracket: u32,
    /// Rung within the bracket (for `Bandit`: the slice ordinal).
    pub rung: u32,
    pub algo: Algo,
    /// Cumulative modeled seconds granted to this tuner after this event.
    pub allocated_s: f64,
    /// Cumulative modeled seconds charged after this event — with
    /// checkpointed extension this grows by exactly the increment.
    pub charged_s: f64,
    /// Cumulative live observations after this event.
    pub observations: u64,
    /// Best observed f so far (∞ if the tuner never observed live).
    pub best_f: f64,
    pub action: RungAction,
}

impl RungEvent {
    /// Tab-separated row (see [`RungEvent::tsv_header`]); floats use fixed
    /// 3-decimal formatting so the fixture diff is byte-stable.
    pub fn tsv_row(&self) -> String {
        format!(
            "{}\t{}\t{}\t{}\t{:.3}\t{:.3}\t{}\t{}\t{}",
            self.policy.name(),
            self.bracket,
            self.rung,
            self.algo.name(),
            self.allocated_s,
            self.charged_s,
            self.observations,
            if self.best_f.is_finite() { format!("{:.6}", self.best_f) } else { "inf".into() },
            self.action.name(),
        )
    }

    pub fn tsv_header() -> &'static str {
        "policy\tbracket\trung\ttuner\talloc_s\tcharged_s\tobs\tbest_f\taction"
    }
}

/// Per-tuner observation guard of the scheduler: the time axis is the
/// intended stop, but a pathological cost model (near-zero durations)
/// must not be able to buy unbounded simulations.
pub const SCHEDULER_OBS_GUARD: u64 = 2048;

/// One tuner's result under a [`CampaignScheduler`].
#[derive(Clone, Debug)]
pub struct SchedulerOutcome {
    pub algo: Algo,
    /// Cumulative modeled seconds this tuner was allocated.
    pub allocated_s: f64,
    /// Modeled seconds actually spent (time is checked pre-dispatch, so
    /// this exceeds `allocated_s` by at most `max_wave_s`).
    pub elapsed_s: f64,
    /// Modeled seconds actually *charged* across every segment of this
    /// tuner's run. Rung extension bills only the increment — resumed
    /// checkpoints spend nothing on the prefix, and replay-fallback
    /// extensions re-simulate the prefix but charge it zero — so this
    /// equals `elapsed_s` up to float association, never a multiple of it.
    pub charged_s: f64,
    /// Costliest single wave of the run — the overshoot bound.
    pub max_wave_s: f64,
    pub observations: u64,
    pub batches: u64,
    /// Configuration the tuner would deploy.
    pub best_theta: Vec<f64>,
    /// Best *observed* f (∞ for tuners that never observe live — they
    /// rank last under every policy: in the wall-clock frame an
    /// unverified model optimum has banked nothing yet).
    pub best_f: f64,
    /// Live observations spent when the best was first observed.
    pub obs_to_best: u64,
    /// Modeled seconds elapsed when the best was first observed — the
    /// time-to-best metric.
    pub time_to_best: f64,
    /// Rung at which `SuccessiveHalving` culled this tuner (`None` =
    /// survived to the end; always `None` under `Equal`).
    pub culled_at_rung: Option<u32>,
    /// Full broker trace of the tuner's final (longest) run: the
    /// time-to-best curve, via [`EvalRecord::model_time`].
    pub trace: Vec<EvalRecord>,
}

/// Runs a set of tuners — by default the whole registry — against one
/// benchmark under ONE shared modeled wall-clock budget, allocating
/// per-tuner time by [`SchedulerPolicy`] and recording per-tuner
/// time-to-best curves. This is the comparison frame of the successor
/// literature (Tuneful, Bao et al.): *time-to-good-configuration*, where
/// a k-probe wave on an m-slot cluster costs ⌈k/m⌉ sub-waves of modeled
/// time (the brokers run with the paper cluster's slot count), not k
/// observations and not one flat wave.
///
/// **Rung extension.** Checkpointable tuners (the noisy-gradient family,
/// random search, Nelder–Mead, TPE — [`Tuner::checkpointable`]) are
/// extended O(increment): each segment resumes from the previous
/// segment's checkpoint on a broker preloaded with the prior spend
/// (`with_prior_spend`) over an objective fast-forwarded to the prior
/// observation count (`advance_evals`), producing a trajectory
/// bit-identical to one uninterrupted run while spending — and charging —
/// only the new waves. Tuners without a checkpoint channel fall back to
/// resume-by-replay: deterministic rerun at the cumulative allocation,
/// with only the elapsed-time *increment* charged (the replayed prefix is
/// simulation bookkeeping, never billed twice).
///
/// [`Tuner::checkpointable`]: crate::tuner::Tuner::checkpointable
#[derive(Clone)]
pub struct CampaignScheduler {
    pub benchmark: Benchmark,
    pub version: HadoopVersion,
    pub seed: u64,
    pub scenario: ScenarioSpec,
    pub algos: Vec<Algo>,
    /// Shared budget: modeled seconds across ALL tuners together.
    pub total_model_time: f64,
    /// Per-tuner observation guard (see [`SCHEDULER_OBS_GUARD`]).
    pub max_obs_per_tuner: u64,
    pub policy: SchedulerPolicy,
}

impl CampaignScheduler {
    pub fn new(
        benchmark: Benchmark,
        version: HadoopVersion,
        seed: u64,
        total_model_time: f64,
    ) -> Self {
        assert!(total_model_time > 0.0, "scheduler needs a positive time budget");
        CampaignScheduler {
            benchmark,
            version,
            seed,
            scenario: ScenarioSpec::default(),
            algos: Algo::all().to_vec(),
            total_model_time,
            max_obs_per_tuner: SCHEDULER_OBS_GUARD,
            policy: SchedulerPolicy::Equal,
        }
    }

    pub fn with_policy(mut self, policy: SchedulerPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_algos(mut self, algos: Vec<Algo>) -> Self {
        assert!(!algos.is_empty());
        self.algos = algos;
        self
    }

    pub fn with_scenario(mut self, scenario: ScenarioSpec) -> Self {
        self.scenario = scenario;
        self
    }

    pub fn with_obs_guard(mut self, max_obs_per_tuner: u64) -> Self {
        self.max_obs_per_tuner = max_obs_per_tuner.max(1);
        self
    }

    /// Number of halving rungs for `k` starters: ⌈log₂ k⌉ — culls fire
    /// after every rung but the last, so the final rung is run by TWO
    /// finalists (k → … → 3 → 2), never a walkover: the last cull
    /// decision is itself made on fully-funded runs.
    fn rungs_for(k: usize) -> usize {
        let (mut r, mut kk) = (0, k);
        while kk > 1 {
            r += 1;
            kk = kk.div_ceil(2);
        }
        r.max(1)
    }

    /// Hyperband bracket count: min(3, ⌈log₂ n⌉), at least 1.
    fn brackets(&self) -> u32 {
        (Self::rungs_for(self.algos.len()) as u32).clamp(1, 3)
    }

    fn fresh_state(&self, algo: Algo) -> ResumeState {
        let cluster = ClusterSpec::paper_cluster();
        let w = profile_for(self.benchmark, 1000);
        let ctx = TunerContext { version: self.version, cluster, workload: w };
        let tuner = registry::create(algo.name(), &ctx)
            .expect("every Algo maps to a registry entry");
        ResumeState {
            algo,
            checkpointable: tuner.checkpointable(),
            checkpoint: None,
            started: false,
            done: false,
            obs: 0,
            batches: 0,
            elapsed_s: 0.0,
            charged_s: 0.0,
            max_wave_s: 0.0,
            trace: Vec::new(),
            best_theta: ParameterSpace::for_version(self.version).default_theta(),
        }
    }

    /// Run (or extend) one tuner to a cumulative allocation of `alloc_s`
    /// modeled seconds. Checkpointable tuners resume from their previous
    /// segment's checkpoint and spend only the increment; the rest replay
    /// from scratch, with only the elapsed increment charged. All brokers
    /// carry the paper cluster's slot count, so a k-probe wave is billed
    /// ⌈k/slots⌉ sub-waves of contended time.
    fn run_segment(&self, st: &mut ResumeState, alloc_s: f64) {
        if st.done {
            return;
        }
        let space = ParameterSpace::for_version(self.version);
        let cluster = ClusterSpec::paper_cluster();
        let w = profile_for(self.benchmark, 1000);
        let ctx = TunerContext {
            version: self.version,
            cluster: cluster.clone(),
            workload: w.clone(),
        };
        let tuner = registry::create(st.algo.name(), &ctx)
            .expect("every Algo maps to a registry entry");
        let slots = cluster.workers() as usize;
        let mut obj = SimObjective::new(space.clone(), cluster, w, self.seed)
            .with_scenario(self.scenario.clone());
        let budget = Budget::obs(self.max_obs_per_tuner).with_model_time(alloc_s);

        if st.checkpointable {
            // O(increment) extension: fast-forward the positional
            // observation stream past the prior segments, preload the
            // broker's meters, resume from the checkpoint. Memo caching
            // stays OFF — a broker-local cache would not survive the
            // segment boundary (see the Tuner trait docs).
            assert!(obj.advance_evals(st.obs), "SimObjective must support stream fast-forward");
            let mut broker = EvalBroker::new(&mut obj, budget)
                .with_cache(CachePolicy::Off)
                .with_slots(slots)
                .with_prior_spend(st.obs, st.batches, st.elapsed_s);
            let prior_elapsed = st.elapsed_s;
            let (out, ck) =
                tuner.tune_resumable(&mut broker, &space, self.seed, st.checkpoint.as_deref());
            st.obs = broker.evals_used();
            st.batches = broker.batches_used();
            st.elapsed_s = broker.elapsed_model_time();
            st.charged_s += st.elapsed_s - prior_elapsed;
            st.max_wave_s = st.max_wave_s.max(broker.max_batch_cost());
            st.trace.extend(broker.take_trace());
            st.best_theta = out.best_theta;
            st.done = ck.is_none();
            st.checkpoint = ck;
        } else {
            // resume by replay: a deterministic rerun at the cumulative
            // allocation reproduces the prior trajectory bit-exactly and
            // extends it; the replayed prefix is simulation bookkeeping
            // and is charged ZERO — only the elapsed increment is billed
            let mut broker = EvalBroker::new(&mut obj, budget)
                .with_cache(tuner.cache_policy())
                .with_slots(slots);
            let out = tuner.tune(&mut broker, &space, self.seed);
            let (prev_obs, prev_elapsed) = (st.obs, st.elapsed_s);
            st.obs = broker.evals_used();
            st.batches = broker.batches_used();
            st.elapsed_s = broker.elapsed_model_time();
            st.charged_s += (st.elapsed_s - prev_elapsed).max(0.0);
            st.max_wave_s = st.max_wave_s.max(broker.max_batch_cost());
            st.trace = broker.take_trace();
            st.best_theta = out.best_theta;
            // no checkpoint channel: a rerun that makes no progress on a
            // strictly larger grant is finished for good
            st.done = st.started && st.obs == prev_obs && st.elapsed_s == prev_elapsed;
        }
        st.started = true;
    }

    fn event(
        &self,
        bracket: u32,
        rung: u32,
        st: &ResumeState,
        alloc: f64,
        action: RungAction,
    ) -> RungEvent {
        RungEvent {
            policy: self.policy,
            bracket,
            rung,
            algo: st.algo,
            allocated_s: alloc,
            charged_s: st.charged_s,
            observations: st.obs,
            best_f: state_best_f(st),
            action,
        }
    }

    /// Run the campaign. Outcomes come back in `algos` order, culled
    /// tuners included (with their partial results and cull rung).
    pub fn run(&self) -> Vec<SchedulerOutcome> {
        self.run_with_events().0
    }

    /// [`run`](CampaignScheduler::run), plus the full allocation audit
    /// trail: one [`RungEvent`] per grant/extension, cull and completion,
    /// in execution order.
    pub fn run_with_events(&self) -> (Vec<SchedulerOutcome>, Vec<RungEvent>) {
        let n = self.algos.len();
        let mut states: Vec<ResumeState> =
            self.algos.iter().map(|&a| self.fresh_state(a)).collect();
        let mut alloc = vec![0.0_f64; n];
        let mut culled: Vec<Option<u32>> = vec![None; n];
        let mut events: Vec<RungEvent> = Vec::new();

        match self.policy {
            SchedulerPolicy::Equal => {
                self.run_rungs(
                    0,
                    false,
                    self.total_model_time,
                    &mut states,
                    &mut alloc,
                    &mut culled,
                    &mut events,
                );
            }
            SchedulerPolicy::SuccessiveHalving => {
                self.run_rungs(
                    0,
                    true,
                    self.total_model_time,
                    &mut states,
                    &mut alloc,
                    &mut culled,
                    &mut events,
                );
            }
            SchedulerPolicy::Hyperband => {
                let brackets = self.brackets();
                let mut carry = 0.0;
                for b in 0..brackets {
                    // revive everyone not terminally done: under Hyperband
                    // an earlier cull is a deferral, not a death sentence —
                    // checkpoints carry the culled tuner's state across
                    // the bracket boundary
                    for c in culled.iter_mut() {
                        *c = None;
                    }
                    let pool = self.total_model_time / brackets as f64 + carry;
                    carry = self.run_rungs(
                        b,
                        true,
                        pool,
                        &mut states,
                        &mut alloc,
                        &mut culled,
                        &mut events,
                    );
                }
            }
            SchedulerPolicy::Bandit => {
                self.run_bandit(&mut states, &mut alloc, &mut events);
            }
        }

        let outcomes = states
            .into_iter()
            .zip(alloc)
            .zip(culled)
            .map(|((st, a), c)| outcome_of(st, a, c))
            .collect();
        (outcomes, events)
    }

    /// One halving bracket (or a single no-cull rung for `Equal`) over the
    /// shared pool. Returns the unspent pool remainder (reclaims from
    /// culled/finished tuners beyond what later rungs redistribute).
    #[allow(clippy::too_many_arguments)]
    fn run_rungs(
        &self,
        bracket: u32,
        cull: bool,
        mut pool: f64,
        states: &mut [ResumeState],
        alloc: &mut [f64],
        culled: &mut [Option<u32>],
        events: &mut Vec<RungEvent>,
    ) -> f64 {
        let mut survivors: Vec<usize> =
            (0..states.len()).filter(|&i| !states[i].done).collect();
        if survivors.is_empty() {
            return pool;
        }
        let rungs = if cull { Self::rungs_for(survivors.len()) } else { 1 };
        for rung in 0..rungs {
            survivors.retain(|&i| !states[i].done);
            if survivors.is_empty() {
                return pool; // everyone terminal: the rest of the clock is unused
            }
            // this rung spends an equal slice of what is left — including
            // everything reclaimed from earlier culls and completions
            let share = pool / (rungs - rung) as f64;
            pool -= share;
            let per = share / survivors.len() as f64;
            for &i in &survivors {
                alloc[i] += per;
            }

            let actions: Vec<RungAction> = survivors
                .iter()
                .map(|&i| {
                    let st = &states[i];
                    if !st.started {
                        RungAction::Ran
                    } else if st.checkpointable {
                        RungAction::Resumed
                    } else {
                        RungAction::Replayed
                    }
                })
                .collect();

            // independent segments fan across the worker pool
            let jobs: Vec<Box<dyn FnOnce() -> ResumeState + Send>> = survivors
                .iter()
                .map(|&i| {
                    let sched = self.clone();
                    let mut st = states[i].clone();
                    let a = alloc[i];
                    Box::new(move || {
                        sched.run_segment(&mut st, a);
                        st
                    }) as _
                })
                .collect();
            let results = run_parallel(jobs, resolve_workers(None));
            for (&i, st) in survivors.iter().zip(results) {
                states[i] = st;
            }

            for (&i, action) in survivors.iter().zip(actions) {
                events.push(self.event(bracket, rung as u32, &states[i], alloc[i], action));
                if states[i].done {
                    // terminal completion: reclaim the unspent grant
                    let unspent = (alloc[i] - states[i].elapsed_s).max(0.0);
                    pool += unspent;
                    alloc[i] -= unspent;
                    events.push(self.event(
                        bracket,
                        rung as u32,
                        &states[i],
                        alloc[i],
                        RungAction::Finished,
                    ));
                }
            }

            if cull && rung + 1 < rungs {
                let live: Vec<usize> =
                    survivors.iter().copied().filter(|&i| !states[i].done).collect();
                if live.len() > 1 {
                    let ranked = rank_by_observed_f(&live, |i| state_best_f(&states[i]));
                    let keep = ranked.len().div_ceil(2);
                    for &i in &ranked[keep..] {
                        culled[i] = Some(rung as u32);
                        // reinvest the culled tuner's remaining time: the
                        // unspent grant moves back into the pool, so Σ
                        // allocations never exceeds the total budget (a
                        // run may overshoot its allocation by one wave —
                        // never reclaim a negative remainder)
                        let unspent = (alloc[i] - states[i].elapsed_s).max(0.0);
                        pool += unspent;
                        alloc[i] -= unspent;
                        events.push(self.event(
                            bracket,
                            rung as u32,
                            &states[i],
                            alloc[i],
                            RungAction::Culled,
                        ));
                    }
                    survivors = ranked[..keep].to_vec();
                    survivors.sort_unstable(); // registry order, deterministic
                } else {
                    survivors = live;
                }
            }
        }
        pool
    }

    /// UCB bandit loop: fixed slices, one tuner extended per slice.
    fn run_bandit(
        &self,
        states: &mut [ResumeState],
        alloc: &mut [f64],
        events: &mut Vec<RungEvent>,
    ) {
        let n = states.len();
        let slice = self.total_model_time / (4.0 * n as f64);
        let mut pool = self.total_model_time;
        let mut pulls = vec![0u64; n];
        let mut reward_sum = vec![0.0_f64; n];
        let mut t: u64 = 0;
        while pool >= slice * (1.0 - 1e-9) {
            let live: Vec<usize> = (0..n).filter(|&i| !states[i].done).collect();
            if live.is_empty() {
                break;
            }
            // warmup pulls one slice per tuner in registry order; after
            // that, the classic UCB trade-off with the exploitation term
            // normalized by the best mean so the two scales compare
            let pick = if let Some(&i) = live.iter().find(|&&i| pulls[i] == 0) {
                i
            } else {
                let mean = |i: usize| reward_sum[i] / pulls[i] as f64;
                let max_mean = live.iter().map(|&i| mean(i)).fold(0.0_f64, f64::max);
                let mut best = live[0];
                let mut best_score = f64::NEG_INFINITY;
                for &i in &live {
                    let exploit = if max_mean > 0.0 { mean(i) / max_mean } else { 0.0 };
                    let explore = (2.0 * (t.max(1) as f64).ln() / pulls[i] as f64).sqrt();
                    let score = exploit + explore;
                    // strict > keeps ties in registry order
                    if score > best_score {
                        best_score = score;
                        best = i;
                    }
                }
                best
            };

            pool -= slice;
            alloc[pick] += slice;
            let action = if !states[pick].started {
                RungAction::Ran
            } else if states[pick].checkpointable {
                RungAction::Resumed
            } else {
                RungAction::Replayed
            };
            let before_best = state_best_f(&states[pick]);
            let before_charged = states[pick].charged_s;
            self.run_segment(&mut states[pick], alloc[pick]);
            let after_best = state_best_f(&states[pick]);
            let dt = (states[pick].charged_s - before_charged).max(1e-9);
            // reward: relative best-f improvement per modeled second; the
            // first live observation counts as a full relative improvement
            let rel = if after_best.is_finite() {
                if before_best.is_finite() {
                    ((before_best - after_best) / before_best.abs().max(1e-9)).max(0.0)
                } else {
                    1.0
                }
            } else {
                0.0
            };
            reward_sum[pick] += rel / dt;
            pulls[pick] += 1;
            t += 1;
            events.push(self.event(0, (t - 1) as u32, &states[pick], alloc[pick], action));
            if states[pick].done {
                let unspent = (alloc[pick] - states[pick].elapsed_s).max(0.0);
                pool += unspent;
                alloc[pick] -= unspent;
                events.push(self.event(
                    0,
                    (t - 1) as u32,
                    &states[pick],
                    alloc[pick],
                    RungAction::Finished,
                ));
            }
        }
    }
}

/// Per-tuner resume ledger the scheduler threads between segments: the
/// tuner's checkpoint (if it has a channel), the cumulative broker meters
/// a resumed broker is preloaded with, and the concatenated trace.
#[derive(Clone, Debug)]
struct ResumeState {
    algo: Algo,
    checkpointable: bool,
    /// Opaque tuner state between segments; `None` before the first
    /// segment and after terminal completion.
    checkpoint: Option<Vec<u8>>,
    started: bool,
    /// Terminal: the tuner finished for good (checkpoint channel returned
    /// `None`, or a replay made no progress on a larger grant).
    done: bool,
    obs: u64,
    batches: u64,
    elapsed_s: f64,
    /// Σ charged modeled seconds across segments (increments only).
    charged_s: f64,
    max_wave_s: f64,
    trace: Vec<EvalRecord>,
    best_theta: Vec<f64>,
}

/// Best observed f across a state's cumulative trace (∞ if none).
fn state_best_f(st: &ResumeState) -> f64 {
    let mut best = f64::INFINITY;
    for r in &st.trace {
        if r.f < best {
            best = r.f;
        }
    }
    best
}

/// Assemble the public outcome from a final resume ledger.
fn outcome_of(st: ResumeState, allocated_s: f64, culled_at_rung: Option<u32>) -> SchedulerOutcome {
    let (mut best_f, mut obs_to_best, mut time_to_best) = (f64::INFINITY, 0, 0.0);
    for r in &st.trace {
        if r.f < best_f {
            best_f = r.f;
            obs_to_best = r.obs;
            time_to_best = r.model_time;
        }
    }
    SchedulerOutcome {
        algo: st.algo,
        allocated_s,
        elapsed_s: st.elapsed_s,
        charged_s: st.charged_s,
        max_wave_s: st.max_wave_s,
        observations: st.obs,
        batches: st.batches,
        best_theta: st.best_theta,
        best_f,
        obs_to_best,
        time_to_best,
        culled_at_rung,
        trace: st.trace,
    }
}

/// Rank candidate indices ascending by observed f, ties (and everything
/// non-finite) broken by index — the `SuccessiveHalving` cull order. NaN
/// keys map to +∞ first: a poisoned trial must rank last (and be culled),
/// not panic the rung or — under `total_cmp`, where NaN sorts *above*
/// +∞ — shuffle legitimate ∞-ranked tuners.
fn rank_by_observed_f(candidates: &[usize], best_f_of: impl Fn(usize) -> f64) -> Vec<usize> {
    let key = |i: usize| {
        let f = best_f_of(i);
        if f.is_nan() {
            f64::INFINITY
        } else {
            f
        }
    };
    let mut ranked = candidates.to_vec();
    ranked.sort_by(|&a, &b| key(a).total_cmp(&key(b)).then(a.cmp(&b)));
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rung_cull_rank_is_nan_and_inf_proof() {
        // one poisoned trial (NaN), one that never observed (+∞), dupes —
        // the cull order stays total, deterministic and panic-free
        let fs = [0.5, f64::NAN, 0.2, f64::INFINITY, f64::NAN, 0.2];
        let idx: Vec<usize> = (0..fs.len()).collect();
        let ranked = rank_by_observed_f(&idx, |i| fs[i]);
        assert_eq!(ranked, vec![2, 5, 0, 1, 3, 4]);
        // the worst half culled by `run()` is the NaN/∞ tail, never a
        // finite performer
        let keep = ranked.len().div_ceil(2);
        assert!(ranked[..keep].iter().all(|&i| fs[i].is_finite()));
    }

    #[test]
    fn algo_label_round_trips_case_insensitively() {
        for algo in Algo::all() {
            assert_eq!(Algo::from_name(algo.label()), Some(algo), "{}", algo.label());
            assert_eq!(
                Algo::from_name(&algo.label().to_uppercase()),
                Some(algo),
                "uppercased {}",
                algo.label()
            );
            assert_eq!(Algo::from_name(&format!("  {} ", algo.name())), Some(algo));
        }
        // legacy aliases stay accepted
        assert_eq!(Algo::from_name("hill"), Some(Algo::HillClimb));
        assert_eq!(Algo::from_name("mronline"), Some(Algo::HillClimb));
        assert_eq!(Algo::from_name("surrogate"), Some(Algo::SpsaSurrogate));
        assert_eq!(Algo::from_name("simplex"), Some(Algo::NelderMead));
        assert_eq!(Algo::from_name("bayesopt"), Some(Algo::Tpe));
        assert_eq!(Algo::from_name("rd-sa"), Some(Algo::Rdsa));
        assert_eq!(Algo::from_name("bogus"), None);
    }

    #[test]
    fn spsa_trial_beats_default() {
        let spec = TrialSpec::new(Benchmark::Terasort, HadoopVersion::V1, Algo::Spsa, 5);
        let out = run_trial(&spec);
        assert!(out.pct_decrease() > 30.0, "decrease {:.1}%", out.pct_decrease());
        // 3 obs per iteration, whole iterations only, within budget
        assert_eq!(out.history.len() as u64 * 3, out.observations);
        assert!(out.observations <= out.spec.budget.max_obs);
        assert!(out.observations >= out.spec.budget.max_obs / 2, "barely tuned");
        assert_eq!(out.profiling_overhead_s, 0.0);
        // the uniform trace mirrors the broker accounting
        assert_eq!(out.eval_trace.len() as u64, out.observations);
    }

    #[test]
    fn default_trial_is_identity() {
        let spec = TrialSpec::new(Benchmark::Grep, HadoopVersion::V2, Algo::Default, 1);
        let out = run_trial(&spec);
        assert!((out.pct_decrease()).abs() < 1e-9);
        assert_eq!(out.observations, 0);
        assert!(out.eval_trace.is_empty());
    }

    #[test]
    fn campaign_runs_parallel_trials() {
        let specs = vec![
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Spsa, 1),
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Random, 1),
            TrialSpec::new(Benchmark::Bigram, HadoopVersion::V1, Algo::Default, 1),
        ];
        let out = run_campaign(specs);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].spec.algo, Algo::Spsa);
        assert_eq!(out[2].spec.algo, Algo::Default);
        // both live-system tuners improve on the default for bigram
        assert!(out[0].pct_decrease() > 20.0, "spsa {:.1}%", out[0].pct_decrease());
        assert!(out[1].pct_decrease() > 0.0, "random {:.1}%", out[1].pct_decrease());
        // random search spends the whole shared budget, to the observation
        assert_eq!(out[1].observations, out[1].spec.budget.max_obs);
    }

    #[test]
    fn scenario_trial_tunes_under_faults() {
        // SPSA observing a faulty heterogeneous cluster must still beat the
        // default configuration evaluated under the same scenario.
        let scenario = ScenarioSpec::default()
            .with_failures(0.05)
            .with_max_attempts(10)
            .with_slow_node(2, 0.6)
            .with_slow_node(5, 0.7)
            .with_speculation(true);
        let spec = TrialSpec::new(Benchmark::Terasort, HadoopVersion::V1, Algo::Spsa, 5)
            .with_scenario(scenario);
        let out = run_trial(&spec);
        assert!(
            out.pct_decrease() > 20.0,
            "under faults only {:.1}% decrease",
            out.pct_decrease()
        );
    }

    #[test]
    fn starfish_trial_reports_overheads() {
        let spec = TrialSpec::new(Benchmark::InvertedIndex, HadoopVersion::V1, Algo::Starfish, 2);
        let out = run_trial(&spec);
        assert!(out.profiling_overhead_s > 0.0);
        assert!(out.model_evals > 100);
        assert!(out.pct_decrease() > 0.0);
        assert_eq!(out.observations, 1, "starfish profiles exactly once");
    }

    // noise-free default-config duration — sizes time budgets in
    // multiples of a real wave, keeping the tests magnitude-independent
    use crate::experiments::walltime::calib_s;

    #[test]
    fn equal_policy_splits_the_shared_clock_evenly() {
        // ~6 default-duration waves of clock per tuner
        let per = 6.0 * (calib_s(Benchmark::Grep, HadoopVersion::V1) + 5.0);
        let total = 4.0 * per;
        let sched = CampaignScheduler::new(Benchmark::Grep, HadoopVersion::V1, 3, total)
            .with_algos(vec![Algo::Default, Algo::Spsa, Algo::Random, Algo::HillClimb]);
        let outs = sched.run();
        assert_eq!(outs.len(), 4);
        for o in &outs {
            assert!((o.allocated_s - per).abs() < 1e-9, "{:?}", o.algo);
            assert!(o.culled_at_rung.is_none(), "Equal never culls");
            assert!(
                o.elapsed_s <= o.allocated_s + o.max_wave_s,
                "{:?} overshot by more than one wave: {} > {} + {}",
                o.algo,
                o.elapsed_s,
                o.allocated_s,
                o.max_wave_s
            );
        }
        // live tuners spend the clock; Default never observes
        assert_eq!(outs[0].observations, 0);
        assert_eq!(outs[0].elapsed_s, 0.0);
        assert!(outs[0].best_f.is_infinite());
        for o in &outs[1..] {
            assert!(o.observations > 0, "{:?} never observed", o.algo);
            assert!(o.best_f.is_finite());
            assert!(o.time_to_best > 0.0 && o.time_to_best <= o.elapsed_s);
            assert!(o.obs_to_best >= 1 && o.obs_to_best <= o.observations);
        }
        // in the wall-clock frame random's 64-probe waves buy far more
        // observations per second than SPSA's 3-probe waves
        let spsa = outs.iter().find(|o| o.algo == Algo::Spsa).unwrap();
        let random = outs.iter().find(|o| o.algo == Algo::Random).unwrap();
        assert!(
            random.observations > spsa.observations,
            "random {} obs vs spsa {} obs under one clock",
            random.observations,
            spsa.observations
        );
    }

    #[test]
    fn successive_halving_reinvests_culled_tuners_remaining_time() {
        // The acceptance assertion. Four tuners, two rungs (4 → 2 → 1).
        // Rung 0 grants each T/8 of the total T. `Default` never observes
        // (best_f = ∞, elapsed 0), so it is culled first and its FULL T/8
        // flows back into the pool. Without reclamation a survivor's final
        // allocation would be T/8 + (T/2)/2 = 0.375·T; with the ≥ T/8
        // reclaim it is ≥ T/8 + (T/2 + T/8)/2 = 0.4375·T. Asserting
        // > 0.42·T pins that culled time really is reinvested.
        let total = 8000.0;
        let sched = CampaignScheduler::new(Benchmark::Grep, HadoopVersion::V1, 3, total)
            .with_algos(vec![Algo::Default, Algo::Spsa, Algo::Random, Algo::HillClimb])
            .with_policy(SchedulerPolicy::SuccessiveHalving);
        let outs = sched.run();
        assert_eq!(outs.len(), 4, "culled tuners still report partial results");

        let default_o = &outs[0];
        assert_eq!(default_o.algo, Algo::Default);
        assert_eq!(default_o.culled_at_rung, Some(0), "∞-ranked tuner culled at rung 0");
        assert_eq!(default_o.elapsed_s, 0.0);
        assert_eq!(
            default_o.allocated_s, 0.0,
            "a culled tuner's unspent grant must move back to the pool"
        );

        let survivors: Vec<_> = outs.iter().filter(|o| o.culled_at_rung.is_none()).collect();
        assert_eq!(survivors.len(), 2, "4 → 2 survivors over two rungs");
        for s in &survivors {
            assert!(
                s.allocated_s > 0.42 * total,
                "{:?} got {:.0}s of {total}s — culled time was not reinvested",
                s.algo,
                s.allocated_s
            );
        }
        // the budget stays a budget: nothing allocated out of thin air
        let granted: f64 = outs.iter().map(|o| o.allocated_s).sum();
        assert!(granted <= total + 1e-6, "allocated {granted} > total {total}");
    }

    #[test]
    fn rung_extension_charges_model_time_once_per_increment() {
        // The satellite bugfix pinned: under SuccessiveHalving a survivor
        // crosses rungs by checkpoint resume (spsa, random) or by replay
        // fallback (hillclimb; Default never observes). Either way the
        // charged model time must equal the final elapsed time — the
        // replayed/resumed prefix is billed exactly once, so Σ charged
        // stays a budget, never a multiple of one.
        let total = 8000.0;
        let sched = CampaignScheduler::new(Benchmark::Grep, HadoopVersion::V1, 3, total)
            .with_algos(vec![Algo::Default, Algo::Spsa, Algo::Random, Algo::HillClimb])
            .with_policy(SchedulerPolicy::SuccessiveHalving);
        let (outs, events) = sched.run_with_events();
        for o in &outs {
            let tol = 1e-9 * o.elapsed_s.max(1.0);
            assert!(
                (o.charged_s - o.elapsed_s).abs() <= tol,
                "{:?}: charged {} vs elapsed {} — a rung extension double-billed its prefix",
                o.algo,
                o.charged_s,
                o.elapsed_s
            );
        }
        // survivors really were extended (two rungs → a Resumed or
        // Replayed event), and every extension's charge is monotone
        assert!(
            events.iter().any(|e| matches!(e.action, RungAction::Resumed | RungAction::Replayed)),
            "no rung extension happened at all"
        );
        let charged: f64 = outs.iter().map(|o| o.charged_s).sum();
        let slack: f64 = outs.iter().map(|o| o.max_wave_s).sum();
        assert!(
            charged <= total + slack + 1e-6,
            "Σ charged {charged} blew the {total}s budget (wave slack {slack})"
        );
    }

    #[test]
    fn hyperband_revives_culled_tuners_across_brackets() {
        let total = 12_000.0;
        let sched = CampaignScheduler::new(Benchmark::Grep, HadoopVersion::V1, 3, total)
            .with_algos(vec![Algo::Spsa, Algo::Random, Algo::HillClimb, Algo::NelderMead])
            .with_policy(SchedulerPolicy::Hyperband);
        let (outs, events) = sched.run_with_events();
        assert_eq!(outs.len(), 4);
        let brackets: std::collections::BTreeSet<u32> =
            events.iter().map(|e| e.bracket).collect();
        assert!(brackets.len() >= 2, "hyperband must run multiple brackets: {brackets:?}");

        // a tuner culled in bracket 0 must reappear (resumed or replayed)
        // in a later bracket — the cull was a deferral
        let culled_b0: Vec<Algo> = events
            .iter()
            .filter(|e| e.bracket == 0 && e.action == RungAction::Culled)
            .map(|e| e.algo)
            .collect();
        assert!(!culled_b0.is_empty(), "an aggressive bracket culls someone");
        for &algo in &culled_b0 {
            assert!(
                events.iter().any(|e| e.bracket > 0
                    && e.algo == algo
                    && matches!(e.action, RungAction::Resumed | RungAction::Replayed)),
                "{algo:?} was culled in bracket 0 and never revived"
            );
        }

        // cumulative meters only ever grow, and charging stays incremental
        for o in &outs {
            let tol = 1e-9 * o.elapsed_s.max(1.0);
            assert!((o.charged_s - o.elapsed_s).abs() <= tol, "{:?}", o.algo);
        }
        let mut seen: std::collections::BTreeMap<Algo, (f64, u64)> = Default::default();
        for e in &events {
            let entry = seen.entry(e.algo).or_insert((0.0, 0));
            assert!(
                e.charged_s >= entry.0 && e.observations >= entry.1,
                "{:?}: cumulative meters went backwards",
                e.algo
            );
            *entry = (e.charged_s, e.observations);
        }
        let granted: f64 = outs.iter().map(|o| o.allocated_s).sum();
        assert!(granted <= total + 1e-6, "allocated {granted} > total {total}");
    }

    #[test]
    fn bandit_reallocates_toward_observed_improvement() {
        // Default banks zero reward (it never observes); SPSA improves
        // every pull. UCB must steer the slices toward SPSA.
        let total = 9000.0;
        let sched = CampaignScheduler::new(Benchmark::Grep, HadoopVersion::V1, 3, total)
            .with_algos(vec![Algo::Default, Algo::Spsa, Algo::Random])
            .with_policy(SchedulerPolicy::Bandit);
        let (outs, events) = sched.run_with_events();
        assert_eq!(outs.len(), 3);
        let by = |a: Algo| outs.iter().find(|o| o.algo == a).unwrap();
        let (default_o, spsa_o) = (by(Algo::Default), by(Algo::Spsa));
        assert!(
            spsa_o.allocated_s > default_o.allocated_s,
            "bandit granted SPSA {:.0}s vs Default {:.0}s",
            spsa_o.allocated_s,
            default_o.allocated_s
        );
        assert!(spsa_o.best_f.is_finite() && spsa_o.observations > 0);
        // warmup pulls everyone once, in registry order
        let first_three: Vec<Algo> = events.iter().take(3).map(|e| e.algo).collect();
        assert_eq!(first_three, vec![Algo::Default, Algo::Spsa, Algo::Random]);
        let granted: f64 = outs.iter().map(|o| o.allocated_s).sum();
        assert!(granted <= total + 1e-6);
        // the audit trail rows render to stable TSV (the gauntlet format)
        for e in &events {
            let row = e.tsv_row();
            assert_eq!(row.split('\t').count(), 9, "{row}");
        }
    }

    #[test]
    fn extending_a_time_budget_replays_the_trajectory_prefix() {
        // The resume-by-replay contract SuccessiveHalving rests on:
        // re-running a tuner with a larger time allocation reproduces the
        // shorter run's observation stream bit-exactly and extends it.
        let run_with = |t: f64| {
            CampaignScheduler::new(Benchmark::Grep, HadoopVersion::V1, 5, t)
                .with_algos(vec![Algo::Spsa])
                .run()
                .remove(0)
        };
        let short = run_with(1200.0);
        let long = run_with(2400.0);
        assert!(
            long.trace.len() >= short.trace.len(),
            "doubling the clock shrank the run"
        );
        for (a, b) in short.trace.iter().zip(&long.trace) {
            assert_eq!(a.f, b.f, "replayed observation diverged");
            assert_eq!(a.theta, b.theta);
            assert_eq!(a.obs, b.obs);
            assert_eq!(a.model_time, b.model_time);
        }
    }

    #[test]
    fn every_algo_runs_under_one_small_budget() {
        // The whole registry through run_trial at a tight shared budget:
        // nothing overspends (run_trial asserts) and outcomes are sane.
        for algo in Algo::all() {
            let spec = TrialSpec::new(Benchmark::Grep, HadoopVersion::V1, algo, 3)
                .with_budget(Budget::obs(24));
            let out = run_trial(&spec);
            assert!(out.observations <= 24, "{}", algo.label());
            assert!(out.tuned_mean_s > 0.0, "{}", algo.label());
        }
    }
}
