//! Campaign orchestration: the leader process that fans tuning trials over
//! worker threads, evaluates outcomes on the simulator, and persists
//! results — the operational shell around the SPSA process of paper §6.

pub mod campaign;
pub mod fingerprint;
pub mod pool;
pub mod results;
pub mod service;
pub mod store;

pub use campaign::{
    evaluate_theta, expand_theta, profile_for, run_campaign, run_trial, run_trial_warmed,
    Algo, CampaignScheduler, RungAction, RungEvent, SchedulerOutcome, SchedulerPolicy,
    TrialOutcome, TrialSpec, WarmStart, DEFAULT_TRIAL_BUDGET, SCHEDULER_OBS_GUARD,
};
pub use fingerprint::{fingerprint_for, Fingerprint};
pub use pool::{default_workers, env_workers, in_pool_worker, resolve_workers, run_parallel};
pub use results::{outcome_json, ResultsDir};
pub use service::{
    parse_script, prune_mask, service_outcome_json, stream_json, ServiceConfig,
    ServiceOutcome, TuningRequest, TuningService,
};
pub use store::{scenario_sig, ObservationStore, StoreKey, StoredObs};
