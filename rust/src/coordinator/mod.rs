//! Campaign orchestration: the leader process that fans tuning trials over
//! worker threads, evaluates outcomes on the simulator, and persists
//! results — the operational shell around the SPSA process of paper §6.

pub mod campaign;
pub mod pool;
pub mod results;

pub use campaign::{
    evaluate_theta, profile_for, run_campaign, run_trial, Algo, CampaignScheduler,
    SchedulerOutcome, SchedulerPolicy, TrialOutcome, TrialSpec, DEFAULT_TRIAL_BUDGET,
    SCHEDULER_OBS_GUARD,
};
pub use pool::{default_workers, env_workers, in_pool_worker, resolve_workers, run_parallel};
pub use results::{outcome_json, ResultsDir};
