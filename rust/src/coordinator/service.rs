//! The multi-tenant tuning service: a long-running, in-process
//! coordinator that admits a stream of tuning requests and amortizes
//! observations across them (ROADMAP item 2 — Tuneful's cross-job
//! observation economy on top of the metered broker).
//!
//! Per request: fingerprint the workload ([`fingerprint_for`]), match it
//! against prior campaigns with the same `(benchmark, version,
//! scenario)` store key, and when the affinity clears the configured
//! threshold, **warm-start** the trial — prior store records are served
//! to the tuner for free (flagged [`ObsSource::Store`], i.e.
//! noise-frozen) and, for direct-search tuners with enough evidence,
//! insignificant dimensions are **pruned** to their defaults (Tuneful
//! §3) before SPSA/TPE ever run. Live observations harvested from the
//! trial's trace are inserted back into the [`ObservationStore`] so the
//! next tenant pays even less.
//!
//! Requests are processed strictly in admission order and every data
//! structure iterates in key order, so replaying the same request
//! stream (same seeds) is **bit-identical** — at any worker count, with
//! or without store hits. `repro serve --script <requests.tsv>` replays
//! a stream from disk and CI diffs two replays byte for byte.
//!
//! [`ObsSource::Store`]: crate::tuner::ObsSource

use crate::tuner::{live_best, Budget};
use crate::util::json::Json;
use crate::workloads::Benchmark;

use super::campaign::{run_trial_warmed, Algo, TrialOutcome, TrialSpec, WarmStart};
use super::fingerprint::{fingerprint_for, Fingerprint};
use super::store::{scenario_sig, version_tag, ObservationStore, DEFAULT_STORE_CAPACITY, DEFAULT_STORE_QUANT};

/// Service knobs. The defaults are what `repro serve` runs with.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Store θ-cell size (coarser than the broker memo, see
    /// [`DEFAULT_STORE_QUANT`]).
    pub store_quant: f64,
    /// Store capacity before deterministic FIFO eviction.
    pub store_capacity: usize,
    /// Minimum fingerprint affinity for a prior campaign to warm-start a
    /// request. 1.0 = identical; a 2× input of the same shape scores
    /// ≈ 0.8 (see [`Fingerprint::affinity`]).
    pub match_threshold: f64,
    /// A dimension freezes when its observed binned-mean f-range is at
    /// most this fraction of the overall observed f-range.
    pub prune_threshold: f64,
    /// Minimum matched store records before pruning is attempted —
    /// below this the evidence is too thin to freeze anything.
    pub min_records_for_pruning: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            store_quant: DEFAULT_STORE_QUANT,
            store_capacity: DEFAULT_STORE_CAPACITY,
            match_threshold: 0.6,
            prune_threshold: 0.05,
            min_records_for_pruning: 12,
        }
    }
}

/// One tenant's tuning request: who asks, and the trial they want.
#[derive(Clone, Debug)]
pub struct TuningRequest {
    pub tenant: String,
    pub spec: TrialSpec,
}

/// What the service hands back per request: the trial outcome plus the
/// amortization story (what was reused, what was frozen, what was
/// actually verified live).
#[derive(Clone, Debug)]
pub struct ServiceOutcome {
    pub tenant: String,
    /// Campaign ordinal assigned by the service (admission order).
    pub campaign: u64,
    pub outcome: TrialOutcome,
    /// `true` when a prior campaign cleared the match threshold and its
    /// records seeded this trial.
    pub warm_started: bool,
    /// The matched campaign's ordinal, if any.
    pub matched_campaign: Option<u64>,
    /// Fingerprint affinity to the matched campaign (0 when cold).
    pub affinity: f64,
    /// Store records served to the broker as free warm-start seeds.
    pub seeded_records: usize,
    /// Indices of parameters frozen to defaults by significance pruning.
    pub frozen_dims: Vec<usize>,
    /// First **live-verified** best: f of the best live observation
    /// (∞ when the trial made none — e.g. a pure store replay).
    pub live_best_f: f64,
    /// Live observations spent when the live best was first achieved.
    pub live_obs_to_best: u64,
    /// Modeled seconds elapsed when the live best was first achieved.
    pub live_time_to_best: f64,
}

struct CampaignInfo {
    id: u64,
    benchmark: Benchmark,
    version_tag: u8,
    scenario_sig: u64,
    fingerprint: Fingerprint,
}

/// Significance-aware dimension pruning (Tuneful §3): rank parameters by
/// the f-variation observed across stored records and freeze the ones
/// that demonstrably do not matter. Per dimension, θ is bucketed into 4
/// bins over [0, 1] and the spread of per-bin mean f is the dimension's
/// observed effect; a dimension freezes only when (a) at least two bins
/// have evidence and (b) the spread is at most `threshold_frac` of the
/// overall observed f-range — so a parameter whose observed f-range
/// exceeds the significance threshold is **never** frozen
/// (property-tested). Returns an all-false mask when the overall range
/// is degenerate.
pub fn prune_mask(records: &[(Vec<f64>, f64)], dim: usize, threshold_frac: f64) -> Vec<bool> {
    const BINS: usize = 4;
    let mut mask = vec![false; dim];
    let finite: Vec<&(Vec<f64>, f64)> = records
        .iter()
        .filter(|(t, f)| t.len() == dim && f.is_finite())
        .collect();
    if finite.len() < 2 {
        return mask;
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for (_, f) in &finite {
        lo = lo.min(*f);
        hi = hi.max(*f);
    }
    let global_range = hi - lo;
    if global_range <= 0.0 {
        return mask; // no observed variation at all — nothing to rank
    }
    let threshold = threshold_frac * global_range;
    for (d, m) in mask.iter_mut().enumerate() {
        let mut sum = [0.0_f64; BINS];
        let mut n = [0_u64; BINS];
        for (t, f) in &finite {
            let x = t[d].clamp(0.0, 1.0);
            let b = ((x * BINS as f64) as usize).min(BINS - 1);
            sum[b] += *f;
            n[b] += 1;
        }
        let means: Vec<f64> =
            (0..BINS).filter(|&b| n[b] > 0).map(|b| sum[b] / n[b] as f64).collect();
        if means.len() < 2 {
            continue; // the records never varied this θ: no evidence to freeze on
        }
        let mut mlo = f64::INFINITY;
        let mut mhi = f64::NEG_INFINITY;
        for m in &means {
            mlo = mlo.min(*m);
            mhi = mhi.max(*m);
        }
        *m = mhi - mlo <= threshold;
    }
    // never hand the trial an all-frozen space
    if mask.iter().all(|&fz| fz) {
        mask = vec![false; dim];
    }
    mask
}

/// Can this algorithm search a pruned (reduced-dimension) space? The
/// model-based tuners derive what-if features from the full parameter
/// vector and must see every dimension; pruning targets the
/// direct-search family — exactly Tuneful's "before SPSA/TPE run".
fn prunable(algo: Algo) -> bool {
    !matches!(algo, Algo::Default | Algo::SpsaSurrogate | Algo::Starfish | Algo::Ppabs)
}

/// The long-running, in-process tuning service.
pub struct TuningService {
    config: ServiceConfig,
    store: ObservationStore,
    campaigns: Vec<CampaignInfo>,
    next_campaign: u64,
}

impl Default for TuningService {
    fn default() -> Self {
        Self::new()
    }
}

impl TuningService {
    pub fn new() -> Self {
        Self::with_config(ServiceConfig::default())
    }

    pub fn with_config(config: ServiceConfig) -> Self {
        let store = ObservationStore::new()
            .with_quant(config.store_quant)
            .with_capacity(config.store_capacity);
        TuningService { config, store, campaigns: Vec::new(), next_campaign: 0 }
    }

    /// The shared observation store (counters, size) for reporting.
    pub fn store(&self) -> &ObservationStore {
        &self.store
    }

    /// Admit one request: fingerprint → match → warm-start/prune → run →
    /// harvest. Strictly sequential and deterministic.
    pub fn submit(&mut self, req: &TuningRequest) -> ServiceOutcome {
        let spec = &req.spec;
        let campaign = self.next_campaign;
        self.next_campaign += 1;
        let fp = fingerprint_for(spec.benchmark, spec.version);
        let vtag = version_tag(spec.version);
        let sig = scenario_sig(&spec.scenario);

        // best-affinity prior campaign over the same store key; ties go
        // to the earliest campaign (stable under replay)
        let mut matched: Option<(u64, f64)> = None;
        for c in &self.campaigns {
            if c.benchmark != spec.benchmark || c.version_tag != vtag || c.scenario_sig != sig
            {
                continue;
            }
            let a = fp.affinity(&c.fingerprint);
            let better = match matched {
                Some((_, best)) => a > best,
                None => true,
            };
            if better {
                matched = Some((c.id, a));
            }
        }
        let matched = matched.filter(|&(_, a)| a >= self.config.match_threshold);

        let (warm, seeded_records, frozen_dims) = match matched {
            Some(_) => {
                let records: Vec<(Vec<f64>, f64)> = self
                    .store
                    .records_for(spec.benchmark, spec.version, &spec.scenario)
                    .iter()
                    .map(|r| (r.theta.clone(), r.f))
                    .collect();
                if records.is_empty() {
                    (None, 0, Vec::new())
                } else {
                    let dim =
                        crate::config::ParameterSpace::for_version(spec.version).dim();
                    let mask = if prunable(spec.algo)
                        && records.len() >= self.config.min_records_for_pruning
                    {
                        prune_mask(&records, dim, self.config.prune_threshold)
                    } else {
                        Vec::new()
                    };
                    let frozen_dims: Vec<usize> = mask
                        .iter()
                        .enumerate()
                        .filter(|(_, &fz)| fz)
                        .map(|(i, _)| i)
                        .collect();
                    let n = records.len();
                    let mut ws = WarmStart::new(records, self.store.quant());
                    ws.frozen = mask;
                    (Some(ws), n, frozen_dims)
                }
            }
            None => (None, 0, Vec::new()),
        };

        let outcome = run_trial_warmed(spec, warm.as_ref());

        // harvest: every live, finite observation joins the store under
        // this campaign's ordinal (first-write-wins per θ cell)
        for r in &outcome.eval_trace {
            if r.source == crate::tuner::ObsSource::Live && r.f.is_finite() {
                self.store.insert(
                    spec.benchmark,
                    spec.version,
                    &spec.scenario,
                    &r.theta,
                    r.f,
                    campaign,
                );
            }
        }
        self.campaigns.push(CampaignInfo {
            id: campaign,
            benchmark: spec.benchmark,
            version_tag: vtag,
            scenario_sig: sig,
            fingerprint: fp,
        });

        let (live_best_f, live_obs_to_best, live_time_to_best) =
            match live_best(&outcome.eval_trace) {
                Some(r) => (r.f, r.obs, r.model_time),
                None => (f64::INFINITY, 0, 0.0),
            };
        ServiceOutcome {
            tenant: req.tenant.clone(),
            campaign,
            warm_started: warm.is_some(),
            matched_campaign: matched.map(|(id, _)| id),
            affinity: matched.map(|(_, a)| a).unwrap_or(0.0),
            seeded_records,
            frozen_dims,
            live_best_f,
            live_obs_to_best,
            live_time_to_best,
            outcome,
        }
    }

    /// Replay a whole request stream in admission order.
    pub fn run_stream(&mut self, reqs: &[TuningRequest]) -> Vec<ServiceOutcome> {
        reqs.iter().map(|r| self.submit(r)).collect()
    }
}

/// Parse a `repro serve` request script: one request per line,
/// whitespace-separated `tenant benchmark version tuner seed budget`
/// columns; blank lines and `#` comments skipped.
pub fn parse_script(text: &str) -> Result<Vec<TuningRequest>, String> {
    let mut reqs = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split_whitespace().collect();
        if cols.len() != 6 {
            return Err(format!(
                "line {}: expected 6 columns (tenant benchmark version tuner seed budget), got {}",
                ln + 1,
                cols.len()
            ));
        }
        let benchmark = Benchmark::from_name(cols[1])
            .ok_or_else(|| format!("line {}: unknown benchmark '{}'", ln + 1, cols[1]))?;
        let version = match cols[2].to_ascii_lowercase().as_str() {
            "v1" | "1" => crate::config::HadoopVersion::V1,
            "v2" | "2" => crate::config::HadoopVersion::V2,
            other => return Err(format!("line {}: unknown version '{other}'", ln + 1)),
        };
        let algo = Algo::from_name(cols[3])
            .ok_or_else(|| format!("line {}: unknown tuner '{}'", ln + 1, cols[3]))?;
        let seed: u64 = cols[4]
            .parse()
            .map_err(|_| format!("line {}: bad seed '{}'", ln + 1, cols[4]))?;
        let budget: u64 = cols[5]
            .parse()
            .map_err(|_| format!("line {}: bad budget '{}'", ln + 1, cols[5]))?;
        reqs.push(TuningRequest {
            tenant: cols[0].to_string(),
            spec: TrialSpec::new(benchmark, version, algo, seed)
                .with_budget(Budget::obs(budget)),
        });
    }
    if reqs.is_empty() {
        return Err("request script contains no requests".into());
    }
    Ok(reqs)
}

/// Deterministic JSON for one service outcome. Excludes every
/// wall-clock-derived field (`tuning_wall_ms`) by construction — the
/// serve replay gate diffs this byte for byte across runs.
pub fn service_outcome_json(o: &ServiceOutcome) -> Json {
    let t = &o.outcome;
    let mut j = Json::obj();
    j.set("tenant", Json::Str(o.tenant.clone()))
        .set("campaign", Json::Num(o.campaign as f64))
        .set("benchmark", Json::Str(t.spec.benchmark.label().into()))
        .set("version", Json::Str(t.spec.version.label().into()))
        .set("tuner", Json::Str(t.spec.algo.label().into()))
        .set("seed", Json::Num(t.spec.seed as f64))
        .set("budget_obs", Json::Num(t.spec.budget.max_obs as f64))
        .set("warm_started", Json::Bool(o.warm_started))
        .set(
            "matched_campaign",
            match o.matched_campaign {
                Some(id) => Json::Num(id as f64),
                None => Json::Null,
            },
        )
        .set("affinity", Json::Num(o.affinity))
        .set("seeded_records", Json::Num(o.seeded_records as f64))
        .set(
            "frozen_dims",
            Json::Arr(o.frozen_dims.iter().map(|&d| Json::Num(d as f64)).collect()),
        )
        .set("observations", Json::Num(t.observations as f64))
        .set("store_hits", Json::Num(t.store_hits as f64))
        .set("noise_frozen", Json::Bool(t.noise_frozen))
        .set("elapsed_model_s", Json::Num(t.elapsed_model_s))
        .set("tuned_mean_s", Json::Num(t.tuned_mean_s))
        .set("tuned_std_s", Json::Num(t.tuned_std_s))
        .set("default_mean_s", Json::Num(t.default_mean_s))
        .set("pct_decrease", Json::Num(t.pct_decrease()))
        .set(
            "live_best_f",
            if o.live_best_f.is_finite() { Json::Num(o.live_best_f) } else { Json::Null },
        )
        .set("live_obs_to_best", Json::Num(o.live_obs_to_best as f64))
        .set("live_time_to_best", Json::Num(o.live_time_to_best))
        .set("tuned_theta", Json::from_f64_slice(&t.tuned_theta));
    j
}

/// Deterministic JSON for a whole replayed stream, plus store counters.
pub fn stream_json(outcomes: &[ServiceOutcome], store: &ObservationStore) -> Json {
    let (inserts, hits, evictions) = store.counters();
    let mut s = Json::obj();
    s.set("size", Json::Num(store.len() as f64))
        .set("inserts", Json::Num(inserts as f64))
        .set("lookup_hits", Json::Num(hits as f64))
        .set("evictions", Json::Num(evictions as f64));
    let mut j = Json::obj();
    j.set(
        "requests",
        Json::Arr(outcomes.iter().map(service_outcome_json).collect()),
    )
    .set("store", s);
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HadoopVersion;

    fn req(tenant: &str, algo: Algo, seed: u64, budget: u64) -> TuningRequest {
        TuningRequest {
            tenant: tenant.into(),
            spec: TrialSpec::new(Benchmark::Grep, HadoopVersion::V1, algo, seed)
                .with_budget(Budget::obs(budget)),
        }
    }

    #[test]
    fn second_request_warm_starts_from_the_first() {
        let mut svc = TuningService::new();
        let cold = svc.submit(&req("alice", Algo::Spsa, 11, 18));
        assert!(!cold.warm_started);
        assert_eq!(cold.outcome.store_hits, 0);
        assert!(!svc.store().is_empty(), "live observations were harvested");
        let warm = svc.submit(&req("bob", Algo::Spsa, 23, 18));
        assert!(warm.warm_started, "same benchmark+version+scenario must match");
        assert_eq!(warm.matched_campaign, Some(0));
        assert!(warm.affinity >= 1.0 - 1e-12, "identical workload: affinity 1");
        assert!(warm.seeded_records > 0);
        assert!(warm.outcome.store_hits > 0, "warm seeds count as store hits");
        // the warm trace starts with free store records at obs 0
        let first = &warm.outcome.eval_trace[0];
        assert_eq!(first.obs, 0);
        assert_eq!(first.source, crate::tuner::ObsSource::Store);
    }

    #[test]
    fn warm_start_reaches_cold_best_with_fewer_live_obs() {
        let mut svc = TuningService::new();
        let cold = svc.submit(&req("alice", Algo::HillClimb, 11, 18));
        let cold_best = cold.live_best_f;
        assert!(cold_best.is_finite());
        let warm = svc.submit(&req("bob", Algo::HillClimb, 23, 18));
        // obs spent when the warm trial's best-so-far first reached the
        // cold trial's best (store seeds replay at obs 0)
        let mut best = f64::INFINITY;
        let mut obs_to_reach = None;
        for r in &warm.outcome.eval_trace {
            if !r.f.is_nan() && r.f < best {
                best = r.f;
            }
            if best <= cold_best {
                obs_to_reach = Some(r.obs);
                break;
            }
        }
        let warm_obs = obs_to_reach.expect("warm run must reach the cold best");
        assert_eq!(warm_obs, 0, "the cold best itself replays for free at obs 0");
    }

    #[test]
    fn different_scenarios_never_cross_match() {
        let mut svc = TuningService::new();
        svc.submit(&req("alice", Algo::Spsa, 11, 12));
        let mut r2 = req("bob", Algo::Spsa, 23, 12);
        r2.spec = r2.spec.with_scenario(crate::sim::ScenarioSpec::default().with_failures(0.05));
        let out = svc.submit(&r2);
        assert!(!out.warm_started, "a faulty scenario must not reuse benign observations");
    }

    #[test]
    fn prune_mask_never_freezes_a_significant_dimension() {
        // dim 0 swings f across its range; dim 1 has no effect
        let mut records = Vec::new();
        for i in 0..16 {
            let x = i as f64 / 15.0;
            records.push((vec![x, (i % 4) as f64 / 3.0], 100.0 + 50.0 * x));
        }
        let mask = prune_mask(&records, 2, 0.05);
        assert!(!mask[0], "a dimension moving f by the full range must stay free");
        assert!(mask[1], "a dimension with no observed effect freezes");
    }

    #[test]
    fn prune_mask_needs_variation_evidence() {
        // every record at the same θ: no bins to compare, nothing freezes
        let records: Vec<(Vec<f64>, f64)> =
            (0..8).map(|i| (vec![0.5, 0.5], 100.0 + i as f64)).collect();
        assert_eq!(prune_mask(&records, 2, 0.05), vec![false, false]);
    }

    #[test]
    fn parse_script_round_trips_and_rejects_garbage() {
        let good = "# stream\nalice terasort v1 spsa 11 24\nbob grep v2 tpe 23 12\n";
        let reqs = parse_script(good).expect("valid script");
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].tenant, "alice");
        assert_eq!(reqs[0].spec.benchmark, Benchmark::Terasort);
        assert_eq!(reqs[1].spec.version, HadoopVersion::V2);
        assert_eq!(reqs[1].spec.budget.max_obs, 12);
        assert!(parse_script("alice terasort v1 spsa 11\n").is_err(), "missing column");
        assert!(parse_script("alice nope v1 spsa 11 24\n").is_err(), "bad benchmark");
        assert!(parse_script("alice terasort v3 spsa 11 24\n").is_err(), "bad version");
        assert!(parse_script("alice terasort v1 nope 11 24\n").is_err(), "bad tuner");
        assert!(parse_script("# only comments\n").is_err(), "empty stream");
    }

    #[test]
    fn replayed_stream_is_bit_identical() {
        let script = "a grep v1 spsa 11 12\nb grep v1 hillclimb 23 12\na grep v1 spsa 11 12\n";
        let reqs = parse_script(script).expect("valid script");
        let run = |reqs: &[TuningRequest]| {
            let mut svc = TuningService::new();
            let outs = svc.run_stream(reqs);
            stream_json(&outs, svc.store()).to_pretty()
        };
        let one = run(&reqs);
        let two = run(&reqs);
        assert_eq!(one, two, "same stream, same seeds → byte-identical result JSON");
        assert!(one.contains("\"warm_started\": true"), "the repeat request warm-starts");
    }
}
