//! Leader/worker thread pool for fanning simulated tuning trials across
//! cores (tokio is unavailable offline; the workload is CPU-bound
//! simulation, so std threads + channels are the right tool anyway).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `jobs` on up to `workers` threads; results return in job order.
pub fn run_parallel<T, F>(jobs: Vec<F>, workers: usize) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }

    // shared queue of (index, job)
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();

    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(thread::spawn(move || loop {
            let job = queue.lock().expect("queue poisoned").pop();
            match job {
                Some((i, f)) => {
                    let out = f();
                    if tx.send((i, out)).is_err() {
                        break;
                    }
                }
                None => break,
            }
        }));
    }
    drop(tx);

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, out) in rx {
        slots[i] = Some(out);
    }
    for h in handles {
        h.join().expect("worker panicked");
    }
    slots.into_iter().map(|s| s.expect("missing result")).collect()
}

/// Default worker count: physical parallelism minus one leader core.
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0usize..32).map(|i| Box::new(move || i * i) as _).collect();
        let out = run_parallel(jobs, 4);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<usize>>());
    }

    #[test]
    fn single_worker_path() {
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> =
            (0u32..5).map(|i| Box::new(move || i + 1) as _).collect();
        assert_eq!(run_parallel(jobs, 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<Box<dyn FnOnce() -> () + Send>> = vec![];
        assert!(run_parallel(jobs, 4).is_empty());
    }

    #[test]
    fn actually_parallel() {
        use std::time::{Duration, Instant};
        let jobs: Vec<Box<dyn FnOnce() -> () + Send>> = (0..8)
            .map(|_| Box::new(|| thread::sleep(Duration::from_millis(50))) as _)
            .collect();
        let t0 = Instant::now();
        run_parallel(jobs, 8);
        assert!(t0.elapsed() < Duration::from_millis(350));
    }
}
