//! Leader/worker thread pool for fanning simulated tuning trials across
//! cores (tokio is unavailable offline; the workload is CPU-bound
//! simulation, so std threads + channels are the right tool anyway).
//!
//! **Nested-parallelism guard.** Campaign-level fan-out (one thread per
//! trial) and objective-level fan-out (one thread per observation inside a
//! trial) compose: `run_parallel` called from inside a pool worker runs its
//! jobs sequentially on that worker instead of spawning a second tier of
//! threads, so total concurrency never exceeds the outer pool's worker
//! count regardless of nesting depth.

use std::cell::Cell;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

thread_local! {
    /// True on threads spawned by `run_parallel` (see module docs).
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread is a pool worker; nested `run_parallel`
/// calls degrade to sequential execution to avoid oversubscription.
pub fn in_pool_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Run `jobs` on up to `workers` threads; results return in job order.
pub fn run_parallel<T, F>(jobs: Vec<F>, workers: usize) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = if in_pool_worker() { 1 } else { workers.clamp(1, n) };
    if workers == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }

    // shared queue of (index, job)
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();

    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(thread::spawn(move || {
            IN_POOL.with(|c| c.set(true));
            loop {
                let job = queue.lock().expect("queue poisoned").pop();
                match job {
                    Some((i, f)) => {
                        let out = f();
                        if tx.send((i, out)).is_err() {
                            break;
                        }
                    }
                    None => break,
                }
            }
        }));
    }
    drop(tx);

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, out) in rx {
        slots[i] = Some(out);
    }
    for h in handles {
        h.join().expect("worker panicked");
    }
    slots.into_iter().map(|s| s.expect("missing result")).collect()
}

/// Default worker count: physical parallelism minus one leader core.
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1).max(1)
}

/// Worker-count override from the `HSPSA_WORKERS` environment variable.
/// `1` forces fully sequential evaluation; `0` — a common "disable
/// parallelism" spelling — clamps to `1` instead of silently falling back
/// to the all-cores default. An unparseable value warns once on stderr and
/// is treated as unset (the user asked for *something*; ignoring it
/// silently would hand them a surprise worker count).
pub fn env_workers() -> Option<usize> {
    let raw = std::env::var("HSPSA_WORKERS").ok()?;
    let parsed = parse_workers(&raw);
    if parsed.is_none() {
        warn_bad_env_workers_once(&raw);
    }
    parsed
}

/// Pure parse of an `HSPSA_WORKERS` value: trims, clamps 0 → 1, `None`
/// for garbage. Split from [`env_workers`] so tests never have to mutate
/// the process environment (getenv/setenv races across test threads).
fn parse_workers(raw: &str) -> Option<usize> {
    raw.trim().parse::<usize>().ok().map(|n| n.max(1))
}

/// One-time warning for a garbage `HSPSA_WORKERS` value (once per process,
/// not once per pool dispatch — objectives resolve workers per batch).
#[allow(clippy::print_stderr)] // deliberate operator-facing warning channel
fn warn_bad_env_workers_once(raw: &str) {
    use std::sync::Once;
    static WARNED: Once = Once::new();
    WARNED.call_once(|| {
        eprintln!(
            "warning: HSPSA_WORKERS={raw:?} is not a number; \
             falling back to the default worker count"
        );
    });
}

/// Worker count for intra-trial observation fan-out: explicit override,
/// else `HSPSA_WORKERS`, else all-but-one core.
pub fn resolve_workers(explicit: Option<usize>) -> usize {
    explicit.or_else(env_workers).unwrap_or_else(default_workers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0usize..32).map(|i| Box::new(move || i * i) as _).collect();
        let out = run_parallel(jobs, 4);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<usize>>());
    }

    #[test]
    fn single_worker_path() {
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> =
            (0u32..5).map(|i| Box::new(move || i + 1) as _).collect();
        assert_eq!(run_parallel(jobs, 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<Box<dyn FnOnce() -> () + Send>> = vec![];
        assert!(run_parallel(jobs, 4).is_empty());
    }

    #[test]
    fn actually_parallel() {
        // Concurrency proof without wall-clock assertions (the old
        // sleep-based test was flaky on loaded CI machines): every job
        // increments an in-flight counter and waits until all 8 jobs are
        // in flight simultaneously before finishing. Only a pool that
        // really runs 8 jobs concurrently lets the count reach 8; a
        // sequential pool would stall at 1 until the deadline fails the
        // test rather than hanging it.
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::time::{Duration, Instant};

        const N: usize = 8;
        let in_flight = Arc::new(AtomicUsize::new(0));
        let max_seen = Arc::new(AtomicUsize::new(0));
        let deadline = Instant::now() + Duration::from_secs(10);

        let jobs: Vec<Box<dyn FnOnce() -> () + Send>> = (0..N)
            .map(|_| {
                let in_flight = Arc::clone(&in_flight);
                let max_seen = Arc::clone(&max_seen);
                Box::new(move || {
                    let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
                    max_seen.fetch_max(now, Ordering::SeqCst);
                    // wait until every job has been observed in flight
                    while max_seen.load(Ordering::SeqCst) < N && Instant::now() < deadline {
                        thread::yield_now();
                    }
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                }) as _
            })
            .collect();
        run_parallel(jobs, N);
        assert_eq!(
            max_seen.load(Ordering::SeqCst),
            N,
            "never saw all {N} jobs in flight at once"
        );
    }

    #[test]
    fn nested_call_degrades_to_sequential() {
        // From inside a pool worker, a nested run_parallel must not spawn
        // threads: its jobs run on the worker thread itself.
        let outer: Vec<Box<dyn FnOnce() -> Vec<bool> + Send>> = (0..4)
            .map(|_| {
                Box::new(move || {
                    assert!(in_pool_worker());
                    let inner: Vec<Box<dyn FnOnce() -> bool + Send>> = (0..4)
                        .map(|_| Box::new(in_pool_worker) as Box<dyn FnOnce() -> bool + Send>)
                        .collect();
                    // if these spawned fresh threads, in_pool_worker would
                    // be false there; sequential execution keeps it true
                    run_parallel(inner, 4)
                }) as _
            })
            .collect();
        for inner in run_parallel(outer, 2) {
            assert!(inner.into_iter().all(|b| b));
        }
    }

    #[test]
    fn leader_thread_is_not_a_worker() {
        assert!(!in_pool_worker());
    }

    #[test]
    fn resolve_workers_explicit_wins() {
        assert_eq!(resolve_workers(Some(3)), 3);
        assert!(resolve_workers(None) >= 1);
    }

    #[test]
    fn workers_value_clamps_zero_and_rejects_garbage() {
        // The parse is tested directly — mutating the real environment
        // would race getenv calls on concurrently running test threads.
        assert_eq!(parse_workers("3"), Some(3));
        assert_eq!(parse_workers(" 2 "), Some(2), "value must be trimmed");
        assert_eq!(parse_workers("1"), Some(1));
        assert_eq!(parse_workers("0"), Some(1), "0 means sequential, not unset");
        assert_eq!(parse_workers("lots"), None, "garbage falls back to default");
        assert_eq!(parse_workers(""), None);
        assert_eq!(parse_workers("-2"), None);
    }
}
