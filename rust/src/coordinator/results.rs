//! Result persistence: write experiment tables to `results/` as markdown +
//! CSV, and campaign outcomes as JSON — the files EXPERIMENTS.md cites.

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};

use crate::util::json::Json;
use crate::util::table::Table;

use super::campaign::TrialOutcome;

/// Writer rooted at a results directory.
pub struct ResultsDir {
    root: PathBuf,
}

impl ResultsDir {
    pub fn new(root: impl AsRef<Path>) -> Result<Self> {
        fs::create_dir_all(root.as_ref())
            .with_context(|| format!("creating {}", root.as_ref().display()))?;
        Ok(ResultsDir { root: root.as_ref().to_path_buf() })
    }

    pub fn default_dir() -> Result<Self> {
        Self::new("results")
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// Write a table as both `<name>.md` and `<name>.csv`.
    pub fn write_table(&self, name: &str, table: &Table) -> Result<()> {
        fs::write(self.path(&format!("{name}.md")), table.to_markdown())?;
        fs::write(self.path(&format!("{name}.csv")), table.to_csv())?;
        Ok(())
    }

    pub fn write_text(&self, name: &str, text: &str) -> Result<()> {
        fs::write(self.path(name), text)?;
        Ok(())
    }

    pub fn write_json(&self, name: &str, json: &Json) -> Result<()> {
        fs::write(self.path(name), json.to_pretty())?;
        Ok(())
    }
}

/// Serialize a trial outcome (without the bulky history) for results JSON.
pub fn outcome_json(o: &TrialOutcome) -> Json {
    let mut j = Json::obj();
    j.set("benchmark", Json::Str(o.spec.benchmark.label().into()))
        .set("version", Json::Str(o.spec.version.label().into()))
        .set("algo", Json::Str(o.spec.algo.label().into()))
        .set("seed", Json::Num(o.spec.seed as f64))
        .set("task_failure_p", Json::Num(o.spec.scenario.task_failure_p))
        .set("tuned_mean_s", Json::Num(o.tuned_mean_s))
        .set("tuned_std_s", Json::Num(o.tuned_std_s))
        .set("default_mean_s", Json::Num(o.default_mean_s))
        .set("pct_decrease", Json::Num(o.pct_decrease()))
        .set("observations", Json::Num(o.observations as f64))
        .set("model_evals", Json::Num(o.model_evals as f64))
        .set("profiling_overhead_s", Json::Num(o.profiling_overhead_s))
        .set("elapsed_model_s", Json::Num(o.elapsed_model_s))
        .set("tuning_wall_ms", Json::Num(o.tuning_wall_ms))
        .set("noise_frozen", Json::Bool(o.noise_frozen))
        .set("store_hits", Json::Num(o.store_hits as f64))
        .set("tuned_theta", Json::from_f64_slice(&o.tuned_theta));
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_all_formats() {
        let dir = std::env::temp_dir().join(format!("hspsa-results-{}", std::process::id()));
        let rd = ResultsDir::new(&dir).unwrap();
        let mut t = Table::new("t").header(vec!["a"]);
        t.row(vec!["1"]);
        rd.write_table("demo", &t).unwrap();
        rd.write_text("note.txt", "hello").unwrap();
        assert!(dir.join("demo.md").exists());
        assert!(dir.join("demo.csv").exists());
        assert!(dir.join("note.txt").exists());
        std::fs::remove_dir_all(dir).ok();
    }
}
