//! Cross-module integration: real benchmark execution → measured profile →
//! simulator → tuners → evaluation, plus failure-injection edge cases.

use hadoop_spsa::baselines::{
    hill_climb, random_search, training_corpus, HillClimbConfig, Ppabs,
};
use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::{HadoopVersion, ParameterSpace};
use hadoop_spsa::coordinator::{evaluate_theta, run_trial, Algo, TrialSpec};
use hadoop_spsa::sim::{simulate, ScenarioSpec, SimOptions};
use hadoop_spsa::tuner::{
    Budget, CachePolicy, EvalBroker, SimObjective, Spsa, SpsaConfig, SpsaVariant,
};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::workloads::{Benchmark, WorkloadProfile};

#[test]
fn full_pipeline_spsa_on_all_benchmarks_v1() {
    // The paper's core claim at reduced budget: SPSA improves every
    // benchmark except (possibly) already-optimal Grep.
    for bench in Benchmark::all() {
        let spec = TrialSpec::new(bench, HadoopVersion::V1, Algo::Spsa, 3);
        let out = run_trial(&spec);
        let floor = if bench == Benchmark::Grep { -10.0 } else { 30.0 };
        assert!(
            out.pct_decrease() > floor,
            "{bench}: only {:.1}% decrease",
            out.pct_decrease()
        );
        // metered by the broker: within budget, in whole 3-obs iterations
        assert!(out.observations <= out.spec.budget.max_obs);
        assert_eq!(out.observations % 3, 0);
        assert!(out.observations > 0);
    }
}

#[test]
fn spsa_variants_all_descend() {
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = Benchmark::InvertedIndex.paper_profile(&mut rng);
    let benign = ScenarioSpec::default();
    let (f_default, _) =
        evaluate_theta(&space, &cluster, &w, &space.default_theta(), 5, 1, &benign);
    for variant in [SpsaVariant::OneSided, SpsaVariant::TwoSided, SpsaVariant::OneMeasurement] {
        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 5);
        let spsa = Spsa::for_space(
            SpsaConfig { variant, max_iters: 30, seed: 9, ..Default::default() },
            &space,
        );
        let res = spsa.run(&mut obj, space.default_theta());
        let (f_tuned, _) = evaluate_theta(&space, &cluster, &w, &res.best_theta, 5, 1, &benign);
        assert!(
            f_tuned < f_default * 0.6,
            "{variant:?}: {f_tuned} vs default {f_default}"
        );
    }
}

#[test]
fn all_live_tuners_improve_terasort() {
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = Benchmark::Terasort.paper_profile(&mut rng);
    let benign = ScenarioSpec::default();
    let (f_default, _) =
        evaluate_theta(&space, &cluster, &w, &space.default_theta(), 5, 2, &benign);

    // both live baselines share the same 60-observation budget through
    // the metered broker (the memo cache on for the revisit-heavy climber)
    let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 7);
    let mut broker =
        EvalBroker::new(&mut obj, Budget::obs(60)).with_cache(CachePolicy::Quantized);
    let hc = hill_climb(&mut broker, space.default_theta(), &HillClimbConfig::default());
    assert!(broker.evals_used() <= 60);
    let (f_hc, _) = evaluate_theta(&space, &cluster, &w, &hc.best_theta, 5, 2, &benign);
    assert!(f_hc < f_default, "hill climbing did not improve");

    let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 8);
    let mut broker = EvalBroker::new(&mut obj, Budget::obs(60));
    let rs = random_search(&mut broker, space.default_theta(), 8);
    assert_eq!(rs.observations, 60, "random search spends the budget exactly");
    let (f_rs, _) = evaluate_theta(&space, &cluster, &w, &rs.best_theta, 5, 2, &benign);
    assert!(f_rs < f_default, "random search did not improve");
}

#[test]
fn ppabs_routes_different_jobs_to_different_clusters() {
    let space = ParameterSpace::v2();
    let cluster = ClusterSpec::paper_cluster();
    let corpus = training_corpus(77);
    let ppabs = Ppabs::train(&space, &cluster, &corpus, 4, 5);
    let mut rng = Rng::seeded(3);
    let tera = Benchmark::Terasort.profile_scaled(200_000, 8 << 30, &mut rng);
    let grep = Benchmark::Grep.profile_scaled(200_000, 8 << 30, &mut rng);
    let theta_tera = ppabs.configure(&tera);
    let theta_grep = ppabs.configure(&grep);
    // terasort and grep signatures must not share a cluster configuration
    assert_ne!(theta_tera, theta_grep, "PPABS collapsed all jobs into one cluster");
}

// ---------------------------------------------------------------------------
// failure injection / degenerate inputs
// ---------------------------------------------------------------------------

fn degenerate_profile() -> WorkloadProfile {
    WorkloadProfile {
        name: "degenerate".into(),
        input_bytes: 1,
        avg_input_record_bytes: 1.0,
        map_selectivity_bytes: 0.0, // map emits nothing
        map_selectivity_records: 0.0,
        avg_map_record_bytes: 1.0,
        combiner_reduction: 1.0,
        has_combiner: false,
        reduce_selectivity_bytes: 0.0,
        partition_skew: 1.0,
        compress_ratio: 1.0,
        map_cpu_ops_per_record: 1.0,
        reduce_cpu_ops_per_record: 1.0,
    }
}

#[test]
fn simulator_survives_zero_output_job() {
    let space = ParameterSpace::v1();
    let r = simulate(
        &ClusterSpec::paper_cluster(),
        &space.default_config(),
        &degenerate_profile(),
        &SimOptions { seed: 1, noise: true, ..Default::default() },
    );
    assert!(r.exec_time_s.is_finite());
    assert!(r.exec_time_s > 0.0);
}

#[test]
fn simulator_survives_tiny_cluster() {
    let space = ParameterSpace::v2();
    let mut w = degenerate_profile();
    w.input_bytes = 1 << 30;
    w.map_selectivity_bytes = 1.0;
    w.map_selectivity_records = 1.0;
    let mut cfg = space.default_config();
    cfg.reduce_tasks = 40; // more reducers than the tiny cluster has slots
    let r = simulate(
        &ClusterSpec::tiny(),
        &cfg,
        &w,
        &SimOptions { seed: 2, noise: true, ..Default::default() },
    );
    assert!(r.exec_time_s.is_finite());
    assert_eq!(r.counters.n_reduces, 40);
    assert!(r.counters.reduce_waves > 1);
}

#[test]
fn extreme_corner_configurations_do_not_break() {
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = Benchmark::Bigram.paper_profile(&mut rng);
    for space in [ParameterSpace::v1(), ParameterSpace::v2()] {
        for corner in [0.0, 1.0] {
            let theta = vec![corner; space.dim()];
            let r = simulate(
                &cluster,
                &space.materialize(&theta),
                &w,
                &SimOptions { seed: 3, noise: true, ..Default::default() },
            );
            assert!(
                r.exec_time_s.is_finite() && r.exec_time_s > 0.0,
                "corner {corner} broke the simulator"
            );
        }
    }
}

#[test]
fn tuning_a_degenerate_job_is_stable() {
    // No map output → flat objective; SPSA must not blow up or escape the box.
    let space = ParameterSpace::v1();
    let mut obj = SimObjective::new(
        space.clone(),
        ClusterSpec::paper_cluster(),
        degenerate_profile(),
        11,
    );
    let spsa = Spsa::for_space(SpsaConfig { max_iters: 10, ..Default::default() }, &space);
    let res = spsa.run(&mut obj, space.default_theta());
    assert!(res.final_theta.iter().all(|t| (0.0..=1.0).contains(t)));
    assert!(res.best_f.is_finite());
}
