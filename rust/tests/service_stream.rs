//! Service-contract integration tests: the committed 3-tenant request
//! fixture replays bit-identically through the tuning service, and the
//! amortization semantics (warm starts, store hits, noise-frozen
//! flagging) hold on the real stream — the same contract the CI
//! `service-smoke` job enforces across worker counts via `repro serve`.

use hadoop_spsa::coordinator::{parse_script, stream_json, TuningService};

const FIXTURE: &str = include_str!("fixtures/service/requests.tsv");

#[test]
fn fixture_stream_replays_bit_identically() {
    let reqs = parse_script(FIXTURE).expect("committed fixture parses");
    assert_eq!(reqs.len(), 5, "the fixture is a 5-request stream");
    let tenants: std::collections::BTreeSet<&str> =
        reqs.iter().map(|r| r.tenant.as_str()).collect();
    assert_eq!(tenants.len(), 3, "three distinct tenants");

    let run = || {
        let mut svc = TuningService::new();
        let outs = svc.run_stream(&reqs);
        stream_json(&outs, svc.store()).to_pretty()
    };
    let first = run();
    let second = run();
    assert_eq!(first, second, "replaying the stream must be byte-identical");
    assert!(
        !first.contains("tuning_wall_ms"),
        "serve JSON must never carry wall-clock fields"
    );
}

#[test]
fn fixture_stream_amortizes_across_tenants() {
    let reqs = parse_script(FIXTURE).expect("committed fixture parses");
    let mut svc = TuningService::new();
    let outs = svc.run_stream(&reqs);

    // request 0 (alice/terasort) is cold; request 1 (bob, same workload,
    // different tuner+seed) warm-starts from alice's campaign
    assert!(!outs[0].warm_started);
    assert_eq!(outs[0].outcome.store_hits, 0);
    assert!(outs[1].warm_started, "bob inherits alice's terasort observations");
    assert_eq!(outs[1].matched_campaign, Some(0));
    assert!(outs[1].seeded_records > 0);
    assert!(outs[1].outcome.store_hits > 0);

    // request 2 (carol/grep) opens a new workload: cold again
    assert!(!outs[2].warm_started, "first grep request has nothing to reuse");

    // request 3 repeats request 0 verbatim — warm, and its store seeds
    // include alice's own earlier best, so the live-verified best is
    // reported separately from the (possibly noise-frozen) deployment
    assert!(outs[3].warm_started);
    assert!(outs[3].affinity >= 1.0 - 1e-12, "identical workload: affinity 1");

    // request 4 (bob/grep) warm-starts from carol's grep campaign
    assert!(outs[4].warm_started);
    assert_eq!(outs[4].matched_campaign, Some(2));

    // the store only ever holds live, finite observations
    let (inserts, _, evictions) = svc.store().counters();
    assert!(inserts > 0);
    assert_eq!(evictions, 0, "default capacity must not evict on a 5-request stream");
    for o in &outs {
        if o.outcome.noise_frozen {
            assert!(
                o.warm_started,
                "a cold trial can never deploy a noise-frozen configuration"
            );
        }
    }
}

#[test]
fn stream_prefix_does_not_perturb_cold_requests() {
    // The first request of any stream is always bit-identical to the
    // same trial run cold on a fresh service: admission of later
    // requests must never rewrite history.
    let reqs = parse_script(FIXTURE).expect("committed fixture parses");
    let mut full = TuningService::new();
    let full_outs = full.run_stream(&reqs);
    let mut solo = TuningService::new();
    let solo_out = solo.submit(&reqs[0]);
    assert_eq!(
        hadoop_spsa::coordinator::service_outcome_json(&full_outs[0]).to_pretty(),
        hadoop_spsa::coordinator::service_outcome_json(&solo_out).to_pretty()
    );
}
