//! Golden-trace snapshot tests: fixed-seed digests (makespan, phase
//! breakdown, counters) for all 5 paper benchmarks × both Hadoop versions
//! × {benign, 5%-failure scenario} — the regression net every future
//! simulator PR runs against.
//!
//! Fixtures live in `rust/tests/golden/traces.tsv`. The suite is
//! self-sealing: cases missing from the fixture file are recorded on the
//! first run (commit the updated file); recorded cases are enforced
//! bit-exactly, with a per-field readable diff on mismatch. To accept an
//! intentional simulator change, rerun with `GOLDEN_REGEN=1` and commit
//! the rewritten fixtures.

// mismatch diffs print to stderr so they survive test-harness capture
#![allow(clippy::print_stderr)]

use std::collections::BTreeMap;
use std::fs;
use std::path::PathBuf;

use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::{HadoopVersion, ParameterSpace};
use hadoop_spsa::coordinator::profile_for;
use hadoop_spsa::sim::{
    simulate, simulate_with_cost_mode, simulate_with_queue, CostMode, JobRunResult, QueueKind,
    ScenarioSpec, SimBuffers, SimOptions,
};
use hadoop_spsa::workloads::Benchmark;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/traces.tsv")
}

/// The 5%-failure scenario tier of the golden matrix: failures + two slow
/// nodes + one mid-job node crash + speculation.
fn faulty_scenario() -> ScenarioSpec {
    ScenarioSpec::default()
        .with_failures(0.05)
        .with_max_attempts(8)
        .with_slow_node(2, 0.6)
        .with_slow_node(5, 0.7)
        .with_crash(240.0, 1)
        .with_speculation(true)
}

/// Bit-exact, human-scannable digest of one run. Float fields carry their
/// raw bit pattern (the byte-stability contract) plus a readable value.
fn digest(r: &JobRunResult) -> String {
    let c = &r.counters;
    format!(
        "exec={:016x}({:.3}s) phases={:016x} wasted={:016x} \
         maps={}/{} reds={}/{} waves={}:{} spills={} spilled_recs={} \
         map_out={} shuffled={} red_spill={} out={} local={} \
         attempts={}:{} fails={}:{} maxfail={} spec={}:{} killed={} \
         nodes_lost={} failed={}",
        r.exec_time_s.to_bits(),
        r.exec_time_s,
        r.phases.total().to_bits(),
        r.phases.wasted.to_bits(),
        c.map_successes,
        c.n_maps,
        c.reduce_successes,
        c.n_reduces,
        c.map_waves,
        c.reduce_waves,
        c.spilled_files,
        c.spilled_records,
        c.map_output_bytes,
        c.shuffled_bytes,
        c.reduce_spilled_bytes,
        c.output_bytes,
        c.data_local_maps,
        c.map_attempts,
        c.reduce_attempts,
        c.map_failures,
        c.reduce_failures,
        c.max_task_failures,
        c.speculative_launches,
        c.speculative_wins,
        c.killed_attempts,
        c.nodes_lost,
        r.job_failed,
    )
}

/// Compute the full golden matrix: key → digest. The default entry point
/// runs the production `simulate` path (whatever queue it ships with).
fn compute_matrix() -> BTreeMap<String, String> {
    compute_matrix_with(None)
}

/// Same matrix with the event-queue implementation pinned explicitly —
/// `None` exercises the production `simulate` path.
fn compute_matrix_with(kind: Option<QueueKind>) -> BTreeMap<String, String> {
    let cluster = ClusterSpec::paper_cluster();
    let mut out = BTreeMap::new();
    for (vtag, version) in [("v1", HadoopVersion::V1), ("v2", HadoopVersion::V2)] {
        let space = ParameterSpace::for_version(version);
        let config = space.default_config();
        for bench in Benchmark::all() {
            let w = profile_for(bench, 1000);
            for (stag, scenario) in
                [("benign", ScenarioSpec::default()), ("fail5", faulty_scenario())]
            {
                let opts = SimOptions { seed: 42, noise: true, scenario };
                let r = match kind {
                    None => simulate(&cluster, &config, &w, &opts),
                    Some(k) => simulate_with_queue(&cluster, &config, &w, &opts, k),
                };
                let key = format!("{vtag}/{}/{stag}", bench.label().replace(' ', "_"));
                out.insert(key, digest(&r));
            }
        }
    }
    out
}

/// Same matrix with the task-costing path pinned explicitly, threading
/// every case through the caller's buffer pool — under `CostMode::Table`
/// this exercises the warm cost cache across all 20 cases (each
/// (version, benchmark, scenario) change resets or revalidates it).
fn compute_matrix_cost(mode: CostMode, bufs: &mut SimBuffers) -> BTreeMap<String, String> {
    let cluster = ClusterSpec::paper_cluster();
    let mut out = BTreeMap::new();
    for (vtag, version) in [("v1", HadoopVersion::V1), ("v2", HadoopVersion::V2)] {
        let space = ParameterSpace::for_version(version);
        let config = space.default_config();
        for bench in Benchmark::all() {
            let w = profile_for(bench, 1000);
            for (stag, scenario) in
                [("benign", ScenarioSpec::default()), ("fail5", faulty_scenario())]
            {
                let opts = SimOptions { seed: 42, noise: true, scenario };
                let r = simulate_with_cost_mode(&cluster, &config, &w, &opts, mode, bufs);
                let key = format!("{vtag}/{}/{stag}", bench.label().replace(' ', "_"));
                out.insert(key, digest(&r));
            }
        }
    }
    out
}

fn load_fixtures() -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    let Ok(text) = fs::read_to_string(fixture_path()) else {
        return out;
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((key, digest)) = line.split_once('\t') {
            out.insert(key.to_string(), digest.to_string());
        }
    }
    out
}

fn write_fixtures(map: &BTreeMap<String, String>) {
    let path = fixture_path();
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir).expect("create golden dir");
    }
    let mut text = String::from(
        "# Golden simulator traces — seed 42, paper cluster, default configs.\n\
         # One line per case: <version>/<benchmark>/<scenario>\\t<digest>.\n\
         # Regenerate intentionally with: GOLDEN_REGEN=1 cargo test --test golden_traces\n",
    );
    for (k, v) in map {
        text.push_str(k);
        text.push('\t');
        text.push_str(v);
        text.push('\n');
    }
    fs::write(&path, text).expect("write golden fixtures");
}

/// Print a per-field diff of two digests (they are whitespace-separated
/// `name=value` tokens).
fn print_field_diff(key: &str, want: &str, got: &str) {
    eprintln!("golden trace mismatch for {key}:");
    let (wt, gt): (Vec<&str>, Vec<&str>) =
        (want.split_whitespace().collect(), got.split_whitespace().collect());
    for i in 0..wt.len().max(gt.len()) {
        let w = wt.get(i).copied().unwrap_or("<missing>");
        let g = gt.get(i).copied().unwrap_or("<missing>");
        if w != g {
            let name = w.split('=').next().unwrap_or("?");
            eprintln!("  {name:<14} expected {w}");
            eprintln!("  {name:<14} got      {g}");
        }
    }
    eprintln!("  full expected: {want}");
    eprintln!("  full got:      {got}");
}

#[test]
fn golden_traces_match_fixtures() {
    let computed = compute_matrix();
    assert_eq!(computed.len(), 20, "5 benchmarks × 2 versions × 2 scenarios");

    if std::env::var("GOLDEN_REGEN").is_ok() {
        write_fixtures(&computed);
        println!("GOLDEN_REGEN set: rewrote {} fixtures", computed.len());
        return;
    }

    let recorded = load_fixtures();
    let mut mismatches = 0;
    let mut fresh = 0;
    let mut merged = recorded.clone();
    for (key, got) in &computed {
        match recorded.get(key) {
            Some(want) if want == got => {}
            Some(want) => {
                print_field_diff(key, want, got);
                mismatches += 1;
            }
            None => {
                merged.insert(key.clone(), got.clone());
                fresh += 1;
            }
        }
    }
    assert_eq!(
        mismatches, 0,
        "{mismatches} golden trace(s) diverged — if the simulator change is \
         intentional, regenerate with GOLDEN_REGEN=1 and commit the fixtures"
    );
    if fresh > 0 {
        write_fixtures(&merged);
        println!(
            "recorded {fresh} new golden fixture(s) — commit rust/tests/golden/traces.tsv"
        );
    }
}

#[test]
fn calendar_and_heap_queues_produce_identical_digests() {
    // The calendar queue replaced the BinaryHeap, and the cost tables +
    // warm cache replaced per-launch direct costing — every fast path must
    // be indistinguishable. All 20 golden cases (5 benchmarks × both
    // versions × benign/fail5) must digest bit-identically under either
    // queue, under direct costing, and under the table/warm path sharing
    // one buffer pool across the whole matrix; all four agree with the
    // production `simulate` path.
    let cal = compute_matrix_with(Some(QueueKind::Calendar));
    let heap = compute_matrix_with(Some(QueueKind::Heap));
    let direct = compute_matrix_cost(CostMode::Direct, &mut SimBuffers::new());
    let mut warm_bufs = SimBuffers::new();
    let table = compute_matrix_cost(CostMode::Table, &mut warm_bufs);
    assert_eq!(cal.len(), 20, "5 benchmarks × 2 versions × 2 scenarios");
    for (key, want) in &cal {
        for (path, got) in [("heap queue", &heap[key]), ("direct costing", &direct[key]),
            ("table costing", &table[key])]
        {
            if want != got {
                print_field_diff(key, want, got);
            }
            assert_eq!(want, got, "{path} diverged on {key}");
        }
    }
    assert_eq!(cal, compute_matrix(), "production path disagrees with pinned variants");

    // Warm engagement proof: replay one golden case twice through the
    // matrix pool. The pool's signature is pinned to the LAST matrix case,
    // so the first replay is a cold reset; the second is a warm benign
    // twin — bit-identical digest, warm hits served from inherited state,
    // and strictly fewer cost evaluations than its cold run.
    let cluster = ClusterSpec::paper_cluster();
    let config = ParameterSpace::for_version(HadoopVersion::V1).default_config();
    let w = profile_for(Benchmark::Terasort, 1000);
    let opts = SimOptions { seed: 42, noise: true, ..Default::default() };
    let cold =
        simulate_with_cost_mode(&cluster, &config, &w, &opts, CostMode::Table, &mut warm_bufs);
    let twin =
        simulate_with_cost_mode(&cluster, &config, &w, &opts, CostMode::Table, &mut warm_bufs);
    assert_eq!(digest(&twin), table["v1/Terasort/benign"], "warm twin diverged from golden");
    assert_eq!(cold.counters.warm_hits, 0, "cold replay must start from a signature reset");
    assert!(twin.counters.warm_hits > 0, "warm twin never hit the warm cache");
    assert!(
        twin.counters.cost_evals < cold.counters.cost_evals,
        "warm twin did not amortize cost evaluations ({} vs {})",
        twin.counters.cost_evals,
        cold.counters.cost_evals
    );
}

#[test]
fn golden_matrix_is_stable_within_process() {
    // Two computations must agree bit-for-bit (the cross-run byte-stability
    // contract, verifiable in-process).
    let a = compute_matrix();
    let b = compute_matrix();
    assert_eq!(a, b);
}

#[test]
fn scenario_digests_differ_from_benign() {
    let m = compute_matrix();
    for (vtag, bench) in [("v1", "Terasort"), ("v2", "Grep")] {
        let benign = &m[&format!("{vtag}/{bench}/benign")];
        let faulty = &m[&format!("{vtag}/{bench}/fail5")];
        assert_ne!(benign, faulty, "{vtag}/{bench}: scenario left no trace");
    }
}

#[test]
fn golden_jobs_all_complete() {
    // p=0.05 with max_attempts=8 cannot exhaust a task (p^8 ≈ 4e-11): every
    // golden case must finish and process each split exactly once.
    for (key, digest) in compute_matrix() {
        assert!(digest.contains("failed=false"), "{key} failed: {digest}");
        let maps = digest.split_whitespace().find(|t| t.starts_with("maps=")).unwrap();
        let (done, total) = maps["maps=".len()..].split_once('/').unwrap();
        assert_eq!(done, total, "{key}: not every split processed ({maps})");
    }
}
