//! Property-based invariant tests (via the in-repo `util::prop`
//! mini-framework, DESIGN.md §7): the algebra every module must satisfy
//! for any input, not just the unit-test fixtures.

use hadoop_spsa::config::{HadoopVersion, ParamKind, ParameterSpace};
use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::engine::{run_job, Split};
use hadoop_spsa::sim::{
    map_output_for_split, simulate, simulate_with_cost_mode, simulate_with_queue, CostMode,
    QueueKind, ScenarioSpec, SimBuffers, SimOptions,
};
use hadoop_spsa::tuner::registry::{self, TunerContext};
use hadoop_spsa::tuner::{
    Budget, CachePolicy, EvalBroker, Objective, QuadraticObjective, SimObjective, Spsa,
    SpsaConfig, SpsaState, Tuner,
};
use hadoop_spsa::util::json::Json;
use hadoop_spsa::util::prop::{assert_close, assert_that, forall};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::whatif::{cost_for_theta, ClusterFeatures};
use hadoop_spsa::workloads::{Benchmark, WorkloadProfile};

fn spaces() -> [ParameterSpace; 2] {
    [ParameterSpace::v1(), ParameterSpace::v2()]
}

fn any_profile(g: &mut hadoop_spsa::util::prop::Gen) -> WorkloadProfile {
    WorkloadProfile {
        name: "prop".into(),
        input_bytes: g.u64_in(64 << 20, 64 << 30),
        avg_input_record_bytes: g.f64_in(20.0, 500.0),
        map_selectivity_bytes: g.f64_in(0.01, 4.0),
        map_selectivity_records: g.f64_in(0.05, 16.0),
        avg_map_record_bytes: g.f64_in(8.0, 300.0),
        combiner_reduction: g.f64_in(0.05, 1.0),
        has_combiner: g.bool(),
        reduce_selectivity_bytes: g.f64_in(0.05, 2.0),
        partition_skew: g.f64_in(1.0, 5.0),
        compress_ratio: g.f64_in(0.05, 1.0),
        map_cpu_ops_per_record: g.f64_in(10.0, 5000.0),
        reduce_cpu_ops_per_record: g.f64_in(10.0, 5000.0),
    }
}

#[test]
fn mu_always_lands_in_hadoop_range() {
    forall("mu in range", 300, |g| {
        for space in spaces() {
            let theta = g.unit_vec(space.dim());
            let vals = space.to_hadoop_values(&theta);
            for (v, p) in vals.iter().zip(space.params()) {
                let x = v.as_f64();
                if p.kind == ParamKind::Bool {
                    assert_that(x == 0.0 || x == 1.0, format!("{}: {x}", p.name))?;
                } else {
                    assert_that(
                        x >= p.min - 1e-9 && x <= p.max + 1e-9,
                        format!("{}: {x} outside [{}, {}]", p.name, p.min, p.max),
                    )?;
                }
                if p.kind == ParamKind::Int {
                    assert_that(x == x.floor(), format!("{} not integral: {x}", p.name))?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn mu_is_monotone_per_coordinate() {
    forall("mu monotone", 200, |g| {
        for space in spaces() {
            let i = g.usize_in(0, space.dim() - 1);
            let mut lo = g.unit_vec(space.dim());
            let mut hi = lo.clone();
            let (a, b) = (g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0));
            lo[i] = a.min(b);
            hi[i] = a.max(b);
            let vlo = space.to_hadoop_values(&lo)[i].as_f64();
            let vhi = space.to_hadoop_values(&hi)[i].as_f64();
            assert_that(vlo <= vhi + 1e-9, format!("coord {i}: {vlo} > {vhi}"))?;
        }
        Ok(())
    });
}

#[test]
fn projection_is_idempotent_and_clipping() {
    forall("projection idempotent", 300, |g| {
        let space = ParameterSpace::v1();
        let mut theta: Vec<f64> =
            (0..space.dim()).map(|_| g.f64_in(-2.0, 3.0)).collect();
        space.project(&mut theta);
        assert_that(theta.iter().all(|t| (0.0..=1.0).contains(t)), "in box")?;
        let once = theta.clone();
        space.project(&mut theta);
        assert_that(theta == once, "idempotent")?;
        Ok(())
    });
}

#[test]
fn perturbation_moves_every_integer_param() {
    forall("integer params move", 200, |g| {
        for space in spaces() {
            let theta: Vec<f64> = (0..space.dim()).map(|_| g.f64_in(0.3, 0.7)).collect();
            let delta = space.sample_perturbation(g.rng());
            let base = space.to_hadoop_values(&theta);
            let pert: Vec<f64> =
                theta.iter().zip(&delta).map(|(t, d)| (t + d).clamp(0.0, 1.0)).collect();
            let moved = space.to_hadoop_values(&pert);
            for (i, p) in space.params().iter().enumerate() {
                if p.kind == ParamKind::Int && p.width() >= 5.0 {
                    assert_that(
                        base[i].as_i64() != moved[i].as_i64(),
                        format!("{} did not move", p.name),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn simulator_is_deterministic_and_sane() {
    forall("sim deterministic + sane", 40, |g| {
        let w = any_profile(g);
        let space = if g.bool() { ParameterSpace::v1() } else { ParameterSpace::v2() };
        let theta = g.unit_vec(space.dim());
        let cfg = space.materialize(&theta);
        let cluster = ClusterSpec::paper_cluster();
        let seed = g.u64_in(1, 1 << 40);
        let opts = SimOptions { seed, noise: true, ..Default::default() };
        let a = simulate(&cluster, &cfg, &w, &opts);
        let b = simulate(&cluster, &cfg, &w, &opts);
        assert_that(a.exec_time_s == b.exec_time_s, "determinism")?;
        assert_that(a.exec_time_s.is_finite() && a.exec_time_s > 0.0, "finite positive")?;
        assert_that(a.maps_done_s <= a.exec_time_s, "maps before end")?;
        let c = &a.counters;
        assert_that(c.data_local_maps <= c.n_maps, "locality bound")?;
        assert_that(c.n_maps >= 1 && c.n_reduces >= 1, "tasks exist")?;
        assert_that(
            c.map_waves >= 1 && c.reduce_waves >= 1,
            "waves at least one",
        )?;
        Ok(())
    });
}

/// A random fault/heterogeneity scenario. `max_attempts` is kept high
/// enough relative to the failure rate that exhausting it is practically
/// impossible (p ≤ 0.3 with ≥ 8 attempts ⇒ P(abort) ≤ 0.3^8 per task), so
/// the completion invariants are checkable.
fn any_scenario(g: &mut hadoop_spsa::util::prop::Gen) -> ScenarioSpec {
    let mut s = ScenarioSpec::default()
        .with_failures(g.f64_in(0.0, 0.3))
        .with_max_attempts(g.u64_in(8, 12));
    if g.bool() {
        s = s.with_crash(g.f64_in(20.0, 500.0), g.u64_in(0, 23) as u32);
    }
    for _ in 0..g.usize_in(0, 3) {
        s = s.with_slow_node(g.u64_in(0, 23) as u32, g.f64_in(0.3, 1.0));
    }
    if g.bool() {
        s = s.with_speculation(true);
    }
    s
}

#[test]
fn scenario_processes_every_split_exactly_once() {
    // Under ANY random scenario the job must complete with every input
    // split and every reducer succeeding exactly once, attempt counts
    // bounded by max.attempts, and the whole thing deterministic per seed.
    forall("scenario exactly-once + deterministic", 25, |g| {
        let mut w = any_profile(g);
        w.input_bytes = g.u64_in(512 << 20, 6 << 30);
        let space = if g.bool() { ParameterSpace::v1() } else { ParameterSpace::v2() };
        let theta = g.unit_vec(space.dim());
        let cfg = space.materialize(&theta);
        let cluster = ClusterSpec::paper_cluster();
        let scenario = any_scenario(g);
        let opts = SimOptions {
            seed: g.u64_in(1, 1 << 40),
            noise: true,
            scenario: scenario.clone(),
        };
        let a = simulate(&cluster, &cfg, &w, &opts);
        let b = simulate(&cluster, &cfg, &w, &opts);
        assert_that(a.exec_time_s == b.exec_time_s, "scenario determinism (exec)")?;
        assert_that(a.counters == b.counters, "scenario determinism (counters)")?;
        let c = &a.counters;
        assert_that(
            c.max_task_failures <= scenario.max_attempts,
            format!("{} failures on one task > max {}", c.max_task_failures, scenario.max_attempts),
        )?;
        if !a.job_failed {
            assert_that(
                c.map_successes == c.n_maps,
                format!("{}/{} splits processed", c.map_successes, c.n_maps),
            )?;
            assert_that(
                c.reduce_successes == c.n_reduces,
                format!("{}/{} reducers processed", c.reduce_successes, c.n_reduces),
            )?;
            assert_that(c.map_attempts >= c.n_maps, "attempts under successes")?;
        }
        assert_that(a.exec_time_s.is_finite() && a.exec_time_s > 0.0, "finite positive")?;
        Ok(())
    });
}

#[test]
fn queue_implementations_are_interchangeable_under_any_scenario() {
    // The pop-order contract at full-simulation level: for ANY workload,
    // ANY configuration, ANY fault scenario and ANY seed, the calendar
    // queue and the legacy binary heap drive bit-identical runs — pop
    // order is a pure function of queued (time, seq), so the physics
    // cannot see which structure served the events.
    forall("calendar ≡ heap at simulation level", 15, |g| {
        let mut w = any_profile(g);
        w.input_bytes = g.u64_in(256 << 20, 4 << 30);
        let space = if g.bool() { ParameterSpace::v1() } else { ParameterSpace::v2() };
        let theta = g.unit_vec(space.dim());
        let cfg = space.materialize(&theta);
        let cluster = ClusterSpec::paper_cluster();
        let opts = SimOptions {
            seed: g.u64_in(1, 1 << 40),
            noise: true,
            scenario: any_scenario(g),
        };
        let cal = simulate_with_queue(&cluster, &cfg, &w, &opts, QueueKind::Calendar);
        let heap = simulate_with_queue(&cluster, &cfg, &w, &opts, QueueKind::Heap);
        assert_that(
            cal.exec_time_s.to_bits() == heap.exec_time_s.to_bits(),
            format!("exec diverged: cal {} heap {}", cal.exec_time_s, heap.exec_time_s),
        )?;
        assert_that(cal.counters == heap.counters, "counters diverged")?;
        assert_that(
            cal.phases.total().to_bits() == heap.phases.total().to_bits(),
            "phase breakdown diverged",
        )?;
        assert_that(cal.job_failed == heap.job_failed, "failure verdict diverged")?;
        Ok(())
    });
}

#[test]
fn cost_tables_and_direct_costing_are_bit_identical() {
    // The costing contract at full-simulation level: for ANY workload, ANY
    // configuration, ANY fault scenario and ANY seed, the per-run cost
    // tables — cold AND warm through a shared buffer pool — and per-launch
    // direct costing drive bit-identical runs. Memoization only dedups
    // evaluations of identical (node class, item, contention) triples, so
    // the physics cannot see which path priced an attempt.
    forall("table ≡ direct costing at simulation level", 12, |g| {
        let mut w = any_profile(g);
        w.input_bytes = g.u64_in(256 << 20, 4 << 30);
        let space = if g.bool() { ParameterSpace::v1() } else { ParameterSpace::v2() };
        let theta = g.unit_vec(space.dim());
        let cfg = space.materialize(&theta);
        let cluster = ClusterSpec::paper_cluster();
        let opts = SimOptions {
            seed: g.u64_in(1, 1 << 40),
            noise: true,
            scenario: any_scenario(g),
        };
        let mut bufs = SimBuffers::new();
        let cold = simulate_with_cost_mode(&cluster, &cfg, &w, &opts, CostMode::Table, &mut bufs);
        let warm = simulate_with_cost_mode(&cluster, &cfg, &w, &opts, CostMode::Table, &mut bufs);
        let direct = simulate_with_cost_mode(
            &cluster,
            &cfg,
            &w,
            &opts,
            CostMode::Direct,
            &mut SimBuffers::new(),
        );
        for (path, r) in [("cold table", &cold), ("warm table", &warm)] {
            assert_that(
                r.exec_time_s.to_bits() == direct.exec_time_s.to_bits(),
                format!(
                    "{path}: exec diverged: {} vs direct {}",
                    r.exec_time_s, direct.exec_time_s
                ),
            )?;
            assert_that(r.counters == direct.counters, format!("{path}: counters diverged"))?;
            assert_that(
                r.phases.total().to_bits() == direct.phases.total().to_bits(),
                format!("{path}: phase breakdown diverged"),
            )?;
            assert_that(
                r.job_failed == direct.job_failed,
                format!("{path}: failure verdict diverged"),
            )?;
        }
        // identical (config, workload, seed) twin ⇒ the warm run must
        // actually reuse inherited state, never re-evaluate more
        assert_that(warm.counters.warm_hits > 0, "warm twin never hit the warm cache")?;
        assert_that(
            warm.counters.cost_evals <= cold.counters.cost_evals,
            "warm twin evaluated more costs than its cold run",
        )?;
        Ok(())
    });
}

#[test]
fn warm_and_cold_percentile_objectives_are_bit_identical() {
    // SimObjective threads one buffer pool (and thus the warm cost cache)
    // through its percentile waves; a sequential warm objective and a
    // parallel one (fresh pools per worker chunk) must observe the exact
    // same values for ANY workload, θ sequence and seed.
    forall("warm ≡ cold percentile objective", 5, |g| {
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let w = any_profile(g);
        let seed = g.u64_in(1, 1 << 40);
        let thetas: Vec<Vec<f64>> = (0..3).map(|_| g.unit_vec(space.dim())).collect();
        let mut warm = SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed)
            .tail_p95(4)
            .with_workers(1);
        let mut cold =
            SimObjective::new(space, cluster, w, seed).tail_p95(4).with_workers(4);
        for (i, t) in thetas.iter().enumerate() {
            let a = warm.eval(t);
            let b = cold.eval(t);
            assert_that(
                a.to_bits() == b.to_bits(),
                format!("θ[{i}]: warm {a} != cold {b}"),
            )?;
        }
        let ba = warm.eval_batch(&thetas);
        let bb = cold.eval_batch(&thetas);
        assert_that(ba == bb, "eval_batch diverged between warm and cold pools")?;
        Ok(())
    });
}

#[test]
fn scenario_conserves_byte_counters() {
    // Byte/record counters come from successful attempts only: a faulty run
    // moves exactly the data of its benign twin (same seed).
    forall("scenario byte conservation", 20, |g| {
        let mut w = any_profile(g);
        w.input_bytes = g.u64_in(512 << 20, 4 << 30);
        let space = if g.bool() { ParameterSpace::v1() } else { ParameterSpace::v2() };
        let theta = g.unit_vec(space.dim());
        let cfg = space.materialize(&theta);
        let cluster = ClusterSpec::paper_cluster();
        let seed = g.u64_in(1, 1 << 40);
        // failures + speculation only: node crashes keep the data flow
        // intact too, but a crash that kills the LAST replica holder can
        // turn local reads remote — byte counters still match; keep the
        // property focused on re-execution.
        let scenario = ScenarioSpec::default()
            .with_failures(g.f64_in(0.05, 0.3))
            .with_max_attempts(12)
            .with_speculation(g.bool());
        let benign =
            simulate(&cluster, &cfg, &w, &SimOptions { seed, noise: true, ..Default::default() });
        let faulty =
            simulate(&cluster, &cfg, &w, &SimOptions { seed, noise: true, scenario });
        if faulty.job_failed {
            return Ok(()); // practically unreachable; nothing to compare
        }
        let (b, f) = (&benign.counters, &faulty.counters);
        assert_that(b.map_output_bytes == f.map_output_bytes, "map output bytes")?;
        assert_that(b.shuffled_bytes == f.shuffled_bytes, "shuffled bytes")?;
        assert_that(b.output_bytes == f.output_bytes, "output bytes")?;
        assert_that(b.spilled_records == f.spilled_records, "spilled records")?;
        assert_that(b.spilled_files == f.spilled_files, "spill files")?;
        assert_that(b.reduce_spilled_bytes == f.reduce_spilled_bytes, "reduce spill")?;
        Ok(())
    });
}

#[test]
fn scenario_failures_never_speed_up_the_job() {
    // Pure failure injection (same seed, keyed noise) adds retry work on
    // the same slot chains: the makespan can only grow, up to the small
    // scheduling-anomaly tolerance of contention re-sampling.
    forall("failures lengthen makespan", 20, |g| {
        let mut w = any_profile(g);
        w.input_bytes = g.u64_in(512 << 20, 4 << 30);
        let space = ParameterSpace::v1();
        let theta = g.unit_vec(space.dim());
        let cfg = space.materialize(&theta);
        let cluster = ClusterSpec::paper_cluster();
        let seed = g.u64_in(1, 1 << 40);
        let benign =
            simulate(&cluster, &cfg, &w, &SimOptions { seed, noise: true, ..Default::default() });
        let scenario =
            ScenarioSpec::default().with_failures(g.f64_in(0.05, 0.3)).with_max_attempts(12);
        let faulty =
            simulate(&cluster, &cfg, &w, &SimOptions { seed, noise: true, scenario });
        if faulty.job_failed {
            return Ok(());
        }
        assert_that(
            faulty.exec_time_s >= benign.exec_time_s * 0.95,
            format!("faulty {} < benign {}", faulty.exec_time_s, benign.exec_time_s),
        )?;
        Ok(())
    });
}

#[test]
fn spill_count_monotone_in_buffer() {
    forall("spills decrease with buffer", 200, |g| {
        let space = ParameterSpace::v1();
        let mut theta = g.unit_vec(space.dim());
        let w = any_profile(g);
        let split = g.u64_in(32 << 20, 256 << 20);
        theta[0] = g.f64_in(0.0, 0.5);
        let small = map_output_for_split(&space.materialize(&theta), &w, split);
        theta[0] = g.f64_in(theta[0], 1.0);
        let big = map_output_for_split(&space.materialize(&theta), &w, split);
        assert_that(
            big.n_spills <= small.n_spills,
            format!("{} > {}", big.n_spills, small.n_spills),
        )
    });
}

#[test]
fn whatif_model_finite_positive_everywhere() {
    forall("model finite positive", 300, |g| {
        let w = any_profile(g);
        for (space, version) in [
            (ParameterSpace::v1(), HadoopVersion::V1),
            (ParameterSpace::v2(), HadoopVersion::V2),
        ] {
            let features = ClusterFeatures::from_spec(&ClusterSpec::paper_cluster(), version);
            let theta = g.unit_vec(space.dim());
            let cost = cost_for_theta(&space, &theta, &w, &features);
            assert_that(
                cost.is_finite() && cost > 0.0,
                format!("cost {cost} for theta {theta:?}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn whatif_model_monotone_in_input_size() {
    forall("model monotone in input", 150, |g| {
        let mut w = any_profile(g);
        let space = ParameterSpace::v1();
        let features =
            ClusterFeatures::from_spec(&ClusterSpec::paper_cluster(), HadoopVersion::V1);
        let theta = g.unit_vec(space.dim());
        w.input_bytes = g.u64_in(256 << 20, 8 << 30);
        let small = cost_for_theta(&space, &theta, &w, &features);
        w.input_bytes *= 4;
        let big = cost_for_theta(&space, &theta, &w, &features);
        assert_that(big >= small * 0.99, format!("4x input got cheaper: {small} -> {big}"))
    });
}

#[test]
fn spsa_iterates_stay_in_box_under_any_seed() {
    forall("spsa in box", 8, |g| {
        let space = ParameterSpace::v1();
        let w = any_profile(g);
        let cluster = ClusterSpec::paper_cluster();
        let seed = g.u64_in(1, 1 << 40);
        let mut obj = SimObjective::new(space.clone(), cluster, w, seed);
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 8, seed, ..Default::default() },
            &space,
        );
        let res = spsa.run(&mut obj, space.default_theta());
        for r in &res.history {
            assert_that(
                r.theta.iter().all(|t| (0.0..=1.0).contains(t)),
                format!("iterate escaped the box at iter {}", r.iter),
            )?;
            assert_that(r.f_theta > 0.0 && r.f_theta.is_finite(), "f finite")?;
        }
        Ok(())
    });
}

#[test]
fn every_registry_tuner_respects_any_budget_and_its_first_observation() {
    // The registry-wide budget algebra: for ANY observation budget N and
    // ANY seed, every tuner (all ten entries) run through a metered broker
    // reports evals_used ≤ N, and its broker-tracked best-so-far is no
    // worse than the first thing it observed — a tuner may fail to
    // improve, but it must never *lose* an observation it already made.
    forall("registry tuners: budget + best-so-far", 6, |g| {
        let version = if g.bool() { HadoopVersion::V1 } else { HadoopVersion::V2 };
        let space = ParameterSpace::for_version(version);
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = Rng::seeded(g.u64_in(1, 1 << 32));
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let ctx = TunerContext { version, cluster: cluster.clone(), workload: w.clone() };
        let budget = g.u64_in(8, 40);
        let seed = g.u64_in(1, 1 << 40);
        for e in registry::TUNERS {
            let tuner = registry::create(e.name, &ctx).expect("registry entry instantiates");
            let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed);
            let mut broker = EvalBroker::new(&mut obj, Budget::obs(budget))
                .with_cache(tuner.cache_policy());
            let out = tuner.tune(&mut broker, &space, seed);
            assert_that(
                broker.evals_used() <= budget,
                format!("{} overspent: {} > {budget}", e.name, broker.evals_used()),
            )?;
            assert_that(
                out.best_theta.len() == space.dim(),
                format!("{} returned a malformed θ", e.name),
            )?;
            if let Some(first) = broker.trace().first() {
                // the tuner's RETURNED best (what a deployment would use)
                // must be no worse than the first thing it observed — it
                // may fail to improve, but never loses an observation it
                // already made. Starfish is exempt: its best_f is a
                // what-if model prediction, not a live observation.
                if e.name != "starfish" {
                    assert_that(
                        out.best_f <= first.f,
                        format!(
                            "{}: returned best {} worse than first obs {}",
                            e.name, out.best_f, first.f
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn every_registry_tuner_respects_any_model_time_budget() {
    // The wall-clock axis algebra, for ANY time cap and ANY seed, across
    // all ten registry entries: (a) the time axis is checked before each
    // wave, never mid-wave, so `elapsed_model_time` may exceed
    // `max_model_time` by AT MOST one batch's cost (`max_batch_cost`,
    // which also covers external `charge`s — PPABS); and (b) time
    // truncation is graceful — the returned best is still no worse than
    // the first observation the tuner made.
    forall("registry tuners: model-time axis", 5, |g| {
        let version = if g.bool() { HadoopVersion::V1 } else { HadoopVersion::V2 };
        let space = ParameterSpace::for_version(version);
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = Rng::seeded(g.u64_in(1, 1 << 32));
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let ctx = TunerContext { version, cluster: cluster.clone(), workload: w.clone() };
        let seed = g.u64_in(1, 1 << 40);
        // size the cap in multiples of a real run so it binds mid-flight
        // regardless of the simulator's absolute magnitudes
        let calib = {
            let mut o =
                SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed).noise_free();
            o.eval(&space.default_theta())
        };
        let cap = calib * g.f64_in(1.5, 8.0);
        for e in registry::TUNERS {
            let tuner = registry::create(e.name, &ctx).expect("registry entry instantiates");
            let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed);
            let mut broker = EvalBroker::new(&mut obj, Budget::obs(400).with_model_time(cap))
                .with_cache(tuner.cache_policy());
            let out = tuner.tune(&mut broker, &space, seed);
            assert_that(
                broker.elapsed_model_time() <= cap + broker.max_batch_cost() + 1e-9,
                format!(
                    "{}: elapsed {} overshoots cap {} by more than one batch ({})",
                    e.name,
                    broker.elapsed_model_time(),
                    cap,
                    broker.max_batch_cost()
                ),
            )?;
            assert_that(
                out.best_theta.len() == space.dim(),
                format!("{} returned a malformed θ under time truncation", e.name),
            )?;
            if let Some(first) = broker.trace().first() {
                if e.name != "starfish" {
                    assert_that(
                        out.best_f <= first.f,
                        format!(
                            "{}: time-truncated best {} worse than first obs {}",
                            e.name, out.best_f, first.f
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn batch_cost_is_max_not_sum_of_member_durations() {
    // The parallelism contract: a dispatched wave's modeled cost is the
    // max of its members' durations plus the dispatch overhead — never
    // the sum. Noise-free quadratic ⇒ durations are exactly the returned
    // values, so the wave cost is computable in closed form.
    forall("batch cost = max (parallelism contract)", 150, |g| {
        let n = g.usize_in(1, 6);
        let k = g.usize_in(1, 12);
        let overhead = g.f64_in(0.0, 20.0);
        let mut obj = QuadraticObjective::new(g.unit_vec(n), 0.0, 1);
        let mut broker =
            EvalBroker::new(&mut obj, Budget::obs(1000)).with_dispatch_overhead(overhead);
        let pts: Vec<Vec<f64>> = (0..k).map(|_| g.unit_vec(n)).collect();
        let fs = broker.try_eval_batch(&pts);
        assert_that(fs.len() == k, "whole batch served")?;
        let max = fs.iter().cloned().fold(0.0_f64, f64::max);
        let sum: f64 = fs.iter().sum();
        assert_close(broker.elapsed_model_time(), max + overhead, 1e-9)?;
        if k > 1 {
            // f ≥ 1 everywhere, so sum > max strictly for k > 1
            assert_that(
                broker.elapsed_model_time() < sum + overhead,
                "wave was billed as a sequential sum",
            )?;
        }
        Ok(())
    });
}

#[test]
fn sim_wave_cost_is_slowest_member_plus_overhead() {
    // Same contract on the real objective: the broker's charge for one
    // wave equals the slowest member's simulated duration (independently
    // recomputed from an identical objective) plus the overhead.
    forall("sim wave cost", 5, |g| {
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = Rng::seeded(g.u64_in(1, 1 << 32));
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let seed = g.u64_in(1, 1 << 40);
        let k = g.usize_in(2, 6);
        let pts: Vec<Vec<f64>> = (0..k).map(|_| g.unit_vec(space.dim())).collect();

        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(100));
        broker.try_eval_batch(&pts);
        let charged = broker.elapsed_model_time();

        let mut twin = SimObjective::new(space, cluster, w, seed);
        twin.eval_batch(&pts);
        let durs = twin.last_durations().expect("SimObjective reports durations");
        let slowest = durs.iter().cloned().fold(0.0_f64, f64::max);
        assert_close(
            charged,
            slowest + hadoop_spsa::tuner::DEFAULT_DISPATCH_OVERHEAD_S,
            1e-9,
        )
    });
}

#[test]
fn spsa_state_json_roundtrip_any_state() {
    forall("spsa checkpoint roundtrip", 100, |g| {
        let n = g.usize_in(1, 16);
        let mut st = SpsaState::fresh(g.unit_vec(n));
        st.iter = g.u64_in(0, 500);
        st.f0 = if g.bool() { Some(g.f64_in(1.0, 1e5)) } else { None };
        st.best_f = g.f64_in(1.0, 1e5);
        st.best_theta = g.unit_vec(n);
        let json = st.to_json();
        let back = SpsaState::from_json(&Json::parse(&json.to_string()).unwrap()).unwrap();
        assert_that(back.theta == st.theta, "theta")?;
        assert_that(back.iter == st.iter, "iter")?;
        assert_that(back.best_theta == st.best_theta, "best theta")?;
        match (back.f0, st.f0) {
            (Some(a), Some(b)) => assert_close(a, b, 1e-12)?,
            (None, None) => {}
            _ => return Err("f0 mismatch".into()),
        }
        Ok(())
    });
}

#[test]
fn checkpointed_tuners_resume_bit_identically_at_any_cut() {
    // The tentpole checkpoint contract, forall over the checkpointable
    // registry subset, ANY seed and ANY cut: a run split at an arbitrary
    // observation budget and resumed at the full budget is bit-identical
    // to the uninterrupted run — same best θ, bit-equal best f, same
    // observation and wave counts, bit-equal modeled wall-clock — and the
    // extension spends only the increment: the resumed broker is preloaded
    // with segment 1's meters, so matching the straight run's totals
    // proves segment 2 issued exactly (total − cut) fresh observations
    // instead of replaying the prefix.
    forall("checkpoint resume ≡ straight run", 3, |g| {
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = Rng::seeded(g.u64_in(1, 1 << 32));
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let ctx = TunerContext {
            version: HadoopVersion::V1,
            cluster: cluster.clone(),
            workload: w.clone(),
        };
        let seed = g.u64_in(1, 1 << 40);
        let full = g.u64_in(40, 90);
        let cut = g.u64_in(1, full - 1);
        for e in registry::TUNERS {
            let tuner = registry::create(e.name, &ctx).expect("registry entry instantiates");
            if !tuner.checkpointable() {
                continue;
            }
            // one segment of the logical run: fresh objective fast-forwarded
            // past the prior observations, broker preloaded with the prior
            // meters — the checkpoint channel's whole resume contract
            let run = |budget: u64, resume: Option<&[u8]>, prior: Option<(u64, u64, f64)>| {
                let mut obj =
                    SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed);
                if let Some((p_obs, _, _)) = prior {
                    assert!(obj.advance_evals(p_obs), "sim objective must fast-forward");
                }
                let mut broker =
                    EvalBroker::new(&mut obj, Budget::obs(budget)).with_cache(CachePolicy::Off);
                if let Some((p_obs, p_batches, p_elapsed)) = prior {
                    broker = broker.with_prior_spend(p_obs, p_batches, p_elapsed);
                }
                let (out, ck) = tuner.tune_resumable(&mut broker, &space, seed, resume);
                (out, ck, broker.evals_used(), broker.batches_used(), broker.elapsed_model_time())
            };
            let (out_s, ck_s, obs_s, batches_s, elapsed_s) = run(full, None, None);
            let (out_1, ck_1, obs_1, batches_1, elapsed_1) = run(cut, None, None);
            let (out_2, ck_2, obs_2, batches_2, elapsed_2) = match &ck_1 {
                Some(bytes) => run(full, Some(bytes), Some((obs_1, batches_1, elapsed_1))),
                // terminal before the cut: the straight run stops at the
                // same intrinsic point, so segment 1 IS the whole run
                None => (out_1, None, obs_1, batches_1, elapsed_1),
            };
            assert_that(
                obs_2 == obs_s,
                format!("{}: cut {cut}/{full}: obs {obs_2} != straight {obs_s}", e.name),
            )?;
            assert_that(
                batches_2 == batches_s,
                format!("{}: cut {cut}/{full}: wave count diverged", e.name),
            )?;
            assert_that(
                elapsed_2.to_bits() == elapsed_s.to_bits(),
                format!("{}: wave grid diverged: {elapsed_2} vs {elapsed_s}", e.name),
            )?;
            assert_that(
                out_2.best_theta == out_s.best_theta,
                format!("{}: best θ diverged after resume", e.name),
            )?;
            assert_that(
                out_2.best_f.to_bits() == out_s.best_f.to_bits(),
                format!("{}: best f diverged: {} vs {}", e.name, out_2.best_f, out_s.best_f),
            )?;
            assert_that(
                ck_2.is_some() == ck_s.is_some(),
                format!("{}: terminality verdict diverged", e.name),
            )?;
        }
        Ok(())
    });
}

#[test]
fn contended_wave_cost_is_chunked_maxima_never_below_flat() {
    // The broker's slot-contention model, forall k probes on m slots: the
    // wave is charged ⌈k/m⌉ sub-waves — the sum of per-chunk-of-m duration
    // maxima in dispatch order plus ONE dispatch overhead. On a noise-free
    // quadratic the durations are the returned values, so the charge has a
    // closed form; it is never below the flat (unlimited-slot) charge,
    // collapses to it bit-exactly when k ≤ m, never exceeds the fully
    // sequential sum, and must not perturb the observed values.
    forall("contended wave ≥ flat max", 150, |g| {
        let n = g.usize_in(1, 6);
        let k = g.usize_in(1, 40);
        let m = g.usize_in(1, 8);
        let overhead = g.f64_in(0.0, 20.0);
        let pts: Vec<Vec<f64>> = (0..k).map(|_| g.unit_vec(n)).collect();

        let mut obj_flat = QuadraticObjective::new(vec![0.5; n], 0.0, 1);
        let mut flat = EvalBroker::new(&mut obj_flat, Budget::obs(1000))
            .with_cache(CachePolicy::Off)
            .with_dispatch_overhead(overhead);
        let fs = flat.try_eval_batch(&pts);
        assert_that(fs.len() == k, "flat broker serves the whole wave")?;

        let mut obj_slots = QuadraticObjective::new(vec![0.5; n], 0.0, 1);
        let mut slotted = EvalBroker::new(&mut obj_slots, Budget::obs(1000))
            .with_cache(CachePolicy::Off)
            .with_dispatch_overhead(overhead)
            .with_slots(m);
        let gs = slotted.try_eval_batch(&pts);
        assert_that(gs == fs, "slot count must not change observed values")?;

        let sum: f64 = fs.iter().sum();
        let chunked: f64 =
            fs.chunks(m).map(|c| c.iter().cloned().fold(0.0_f64, f64::max)).sum();
        assert_close(slotted.elapsed_model_time(), chunked + overhead, 1e-9)?;
        assert_that(
            slotted.elapsed_model_time() >= flat.elapsed_model_time() - 1e-9,
            format!(
                "contention made the wave cheaper: {} slotted vs {} flat (k={k} m={m})",
                slotted.elapsed_model_time(),
                flat.elapsed_model_time()
            ),
        )?;
        if k <= m {
            assert_that(
                slotted.elapsed_model_time().to_bits() == flat.elapsed_model_time().to_bits(),
                "k ≤ m: one sub-wave must charge exactly the flat cost",
            )?;
        }
        assert_that(
            slotted.elapsed_model_time() <= sum + overhead + 1e-9,
            "contention exceeded the fully sequential sum",
        )?;
        Ok(())
    });
}

#[test]
fn wordcount_total_is_partition_invariant() {
    // The reduce output must be the same data regardless of how many
    // partitions the engine uses.
    forall("engine partition invariance", 30, |g| {
        let mut rng = Rng::seeded(g.u64_in(1, 1 << 40));
        let bench = *g.pick(&[Benchmark::Bigram, Benchmark::Grep, Benchmark::WordCooccurrence]);
        let splits = bench.generate_input(20_000, 7_000, &mut rng);
        let n1 = g.u64_in(1, 4) as u32;
        let n2 = g.u64_in(5, 16) as u32;
        let a = run_job(&bench.job(), &splits, n1);
        let b = run_job(&bench.job(), &splits, n2);
        let mut ra: Vec<_> = a.all_records().into_iter().cloned().collect();
        let mut rb: Vec<_> = b.all_records().into_iter().cloned().collect();
        ra.sort();
        rb.sort();
        assert_that(ra == rb, format!("{bench}: outputs differ between {n1} and {n2} partitions"))
    });
}

#[test]
fn fingerprint_affinity_is_reflexive_and_scale_monotone() {
    // The service matching algebra, for ANY fingerprint: a job matches
    // itself at exactly 1.0, and growing only the input size (identical
    // shape) strictly and monotonically lowers the affinity — a 2× input
    // of the same shape always scores below the identical job.
    use hadoop_spsa::coordinator::Fingerprint;
    forall("fingerprint reflexive + scale monotone", 200, |g| {
        let n = g.usize_in(1, 24);
        let a = Fingerprint {
            log2_input: g.f64_in(20.0, 40.0),
            shape: (0..n).map(|_| g.f64_in(0.0, 5.0)).collect(),
        };
        assert_that(a.affinity(&a) == 1.0, "reflexive: identical job scores exactly 1")?;
        let (d1, d2) = (g.f64_in(0.1, 3.0), g.f64_in(3.0, 10.0));
        let mut near = a.clone();
        near.log2_input += d1;
        let mut far = a.clone();
        far.log2_input += d2;
        assert_that(
            a.affinity(&near) < 1.0,
            "a larger input of the same shape scores strictly below self",
        )?;
        assert_that(
            a.affinity(&far) < a.affinity(&near),
            format!(
                "affinity not monotone in size distance: +{d2} doublings scored {} vs +{d1} at {}",
                a.affinity(&far),
                a.affinity(&near)
            ),
        )?;
        assert_that(
            a.affinity(&near) == near.affinity(&a),
            "affinity is symmetric",
        )?;
        Ok(())
    });
}

#[test]
fn pruning_never_freezes_a_parameter_with_observed_effect() {
    // The Tuneful-pruning safety property, for ANY record set built from
    // a known generative model: a dimension that demonstrably moves f
    // across (essentially) the whole observed range must never be frozen
    // to its default, at any significance threshold the service would
    // actually use.
    use hadoop_spsa::coordinator::prune_mask;
    forall("pruning spares significant dims", 150, |g| {
        let dim = g.usize_in(2, 8);
        let hot = g.usize_in(0, dim - 1);
        let amp = g.f64_in(10.0, 1000.0);
        let threshold = g.f64_in(0.01, 0.2);
        // f = 100 + amp·θ_hot + tiny noise; every other dim is inert
        let records: Vec<(Vec<f64>, f64)> = (0..32)
            .map(|_| {
                let theta = g.unit_vec(dim);
                let f = 100.0 + amp * theta[hot] + g.f64_in(-0.005, 0.005) * amp;
                (theta, f)
            })
            .collect();
        let mask = prune_mask(&records, dim, threshold);
        assert_that(mask.len() == dim, "mask covers every dimension")?;
        assert_that(
            !mask[hot],
            format!("dim {hot} moves f by the full observed range yet was frozen"),
        )?;
        assert_that(
            !mask.iter().all(|&fz| fz),
            "pruning must never freeze the whole space",
        )?;
        Ok(())
    });
}

#[test]
fn terasort_preserves_every_record() {
    forall("terasort record preservation", 20, |g| {
        let mut rng = Rng::seeded(g.u64_in(1, 1 << 40));
        let n_records = g.u64_in(50, 500);
        let data = hadoop_spsa::workloads::corpus::generate_tera(n_records, &mut rng);
        let splits = vec![Split::Fixed { data, record_len: 100 }];
        let out = run_job(&Benchmark::Terasort.job(), &splits, g.u64_in(1, 8) as u32);
        let total: usize = out.partitions.iter().map(|p| p.len()).sum();
        assert_that(total as u64 == n_records, format!("{total} != {n_records}"))
    });
}
