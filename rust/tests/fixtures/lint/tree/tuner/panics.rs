//! panic-hygiene fixture: unwrap/expect/panic! in library code are
//! findings; `unwrap_or*` and `#[test]` functions are not.

pub fn bad_unwrap(o: Option<u8>) -> u8 {
    o.unwrap()
}

pub fn bad_expect(r: Result<u8, ()>) -> u8 {
    r.expect("fixture")
}

pub fn bad_panic(x: u8) -> u8 {
    if x > 250 {
        panic!("fixture overflow");
    }
    x
}

pub fn guard_unwrap_or(o: Option<u8>) -> u8 {
    o.unwrap_or(0).min(o.unwrap_or_default())
}

pub fn allowed(o: Option<u8>) -> u8 {
    o.unwrap() // lint:allow(panic-hygiene): fixture — caller guarantees Some
}

#[test]
fn test_guard() {
    // exempt: tests panic on purpose
    assert_eq!(bad_unwrap(Some(1)), 1);
    Some(3u8).unwrap();
}
