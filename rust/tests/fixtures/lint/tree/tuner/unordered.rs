//! unordered-map fixture: true positives, a justified suppression, and a
//! test-module guard. (Fixture files are lint inputs, never compiled.)

use std::collections::HashMap;

pub fn build() -> HashMap<String, u64> {
    HashMap::new()
}

// lint:allow(unordered-map): fixture — keyed lookups only, never iterated
pub fn allowed() -> std::collections::HashMap<u8, u8> {
    Default::default()
}

#[cfg(test)]
mod tests {
    #[test]
    fn guard() {
        // exempt: test code may hash freely
        let _m = std::collections::HashSet::<u8>::new();
    }
}
