//! seed-discipline fixture: foreign RNGs and hand-built generator state
//! are findings; `-> Rng {` signatures and `Rng::seeded` keyed streams
//! are not.

use crate::util::rng::Rng;

pub fn foreign() -> u64 {
    let mut r = thread_rng();
    r.next_u64()
}

pub fn hand_built() -> Rng {
    Rng { s: [1, 2, 3, 4], gauss_spare: None }
}

pub fn allowed() -> u64 {
    // lint:allow(seed-discipline): fixture — documenting the foreign-RNG pattern
    StdRng::seed_from_u64(7).next_u64()
}

pub fn keyed(seed: u64, round: u64) -> Rng {
    Rng::seeded(seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}
