//! env-read fixture: the sanctioned (coordinator/pool.rs, env_workers)
//! location reads the environment without a finding; any other function
//! in scope does not.

/// Mirrors the real `coordinator::pool::env_workers` — the one sanctioned
/// env knob.
pub fn env_workers() -> Option<usize> {
    let raw = std::env::var("HSPSA_WORKERS").ok()?;
    raw.trim().parse().ok()
}

pub fn sneaky_knob() -> bool {
    std::env::var("HSPSA_SNEAKY").is_ok()
}
