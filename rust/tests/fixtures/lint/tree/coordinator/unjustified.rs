//! suppression-rule fixture: a `lint:allow` with no justification is
//! itself a finding and silences nothing; so is a typo'd rule name.

pub fn not_allowed() -> u64 {
    // lint:allow(wall-clock)
    let _t = std::time::SystemTime::now();
    0
}

// lint:allow(no-such-rule): the rule name is a typo
pub fn typo() -> u64 {
    0
}
