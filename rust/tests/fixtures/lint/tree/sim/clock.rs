//! wall-clock fixture: a true positive, a justified suppression, and a
//! test-module guard.

pub fn bad() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}

pub fn allowed() -> u64 {
    // lint:allow(wall-clock): fixture — reporting-only timer, never enters modeled results
    let _t = std::time::SystemTime::now();
    0
}

#[cfg(test)]
mod tests {
    #[test]
    fn guard() {
        // exempt: tests may time things
        let _ = std::time::Instant::now();
    }
}
