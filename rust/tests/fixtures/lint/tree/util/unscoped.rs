//! Scope guard: util/ is outside the determinism scope, so an unordered
//! map here is fine (nothing in util/ feeds replayed trajectories).

use std::collections::HashMap;

pub fn ok() -> HashMap<u8, u8> {
    HashMap::new()
}
