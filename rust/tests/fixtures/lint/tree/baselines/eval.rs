//! unmetered-eval fixture: direct `.eval`/`.eval_batch` method calls are
//! findings; trait declarations, impl headers and string mentions are not.

pub const DOC: &str = "never call .eval( directly — go through the broker";

pub trait CostEvaluator {
    fn dim(&self) -> usize;
    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64>;
}

pub trait Objective {
    fn eval(&mut self, theta: &[f64]) -> f64;
}

pub fn bad_batch(e: &mut dyn CostEvaluator, pts: &[Vec<f64>]) -> Vec<f64> {
    e.eval_batch(pts)
}

pub fn bad_single(o: &mut dyn Objective, t: &[f64]) -> f64 {
    o.eval(t)
}

pub fn allowed(e: &mut dyn CostEvaluator, pts: &[Vec<f64>]) -> Vec<f64> {
    e.eval_batch(pts) // lint:allow(unmetered-eval): fixture — model-side evaluator, no live observation
}
