//! End-to-end tests for `repro lint`: the fixture tree pins every rule's
//! true positives, suppressions, and false-positive guards to exact
//! counts; the committed baseline must gate the real `rust/src` tree
//! clean; and an injected violation must fail the gate through the same
//! JSON differ CI consumes.

use std::collections::BTreeMap;
use std::fs;
use std::path::PathBuf;

use hadoop_spsa::analysis::baseline::Baseline;
use hadoop_spsa::analysis::{lint_source, lint_tree, report, rules};

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

fn rule_counts(findings: &[hadoop_spsa::analysis::Finding]) -> BTreeMap<&'static str, usize> {
    let mut counts = BTreeMap::new();
    for f in findings {
        *counts.entry(f.rule).or_insert(0) += 1;
    }
    counts
}

#[test]
fn fixture_tree_produces_exact_per_rule_counts() {
    let report = lint_tree(&repo_path("rust/tests/fixtures/lint/tree")).expect("lint fixtures");
    assert_eq!(report.files_scanned, 8);
    assert_eq!(report.suppressed, 6, "justified in-fixture suppressions");
    let counts = rule_counts(&report.findings);
    let expect: BTreeMap<&str, usize> = [
        ("unordered-map", 3),
        ("wall-clock", 2),
        ("env-read", 1),
        ("seed-discipline", 2),
        ("unmetered-eval", 2),
        ("panic-hygiene", 3),
        ("suppression", 2),
    ]
    .into_iter()
    .collect();
    assert_eq!(counts, expect, "all findings: {:#?}", report.findings);
    assert_eq!(report.findings.len(), 15);
}

#[test]
fn every_rule_in_the_registry_fires_on_the_fixture_tree() {
    let report = lint_tree(&repo_path("rust/tests/fixtures/lint/tree")).expect("lint fixtures");
    let counts = rule_counts(&report.findings);
    for rule in rules::all() {
        assert!(
            counts.contains_key(rule.name),
            "rule '{}' has no fixture coverage",
            rule.name
        );
    }
}

#[test]
fn committed_baseline_gates_the_real_tree_clean() {
    let report = lint_tree(&repo_path("rust/src")).expect("lint rust/src");
    let src = fs::read_to_string(repo_path("rust/tests/fixtures/lint/baseline.json"))
        .expect("read committed baseline");
    let baseline = Baseline::parse(&src).expect("parse committed baseline");
    let diff = baseline.diff(&report);
    assert!(
        diff.new.is_empty(),
        "unbaselined findings in rust/src — fix them, suppress with a justified \
         lint:allow, or rerun `repro lint --update-baseline`:\n{:#?}",
        diff.new
    );
    assert!(
        diff.unjustified.is_empty(),
        "baseline entries missing a justification: {:#?}",
        diff.unjustified
    );
    assert!(
        diff.stale.is_empty(),
        "stale baseline entries (the finding was fixed — prune with \
         `repro lint --update-baseline`): {:#?}",
        diff.stale
    );
}

#[test]
fn committed_baseline_is_in_canonical_serialized_form() {
    // `--update-baseline` must be a no-op on a clean tree: re-serializing
    // the parsed ledger reproduces the committed bytes exactly.
    let src = fs::read_to_string(repo_path("rust/tests/fixtures/lint/baseline.json"))
        .expect("read committed baseline");
    let baseline = Baseline::parse(&src).expect("parse committed baseline");
    let mut reserialized = baseline.to_json().to_pretty();
    // to_pretty ends with one newline, as the committed file does
    assert_eq!(reserialized.pop(), Some('\n'));
    assert_eq!(src.trim_end(), reserialized, "baseline.json is not in canonical form");
    for e in &baseline.entries {
        assert!(
            !e.justification.is_empty(),
            "entry without justification: {} {} {:?}",
            e.rule,
            e.file,
            e.text
        );
    }
}

#[test]
fn injected_violation_fails_the_gate_through_the_json_differ() {
    // Simulate the CI gate on a tree where someone lands a HashMap in
    // tuner code: the finding must surface in the JSON report's `new`
    // array even with the full committed baseline applied.
    let mut report = lint_tree(&repo_path("rust/src")).expect("lint rust/src");
    let injected = "pub fn memo() -> std::collections::HashMap<u64, f64> {\n\
                    \x20   std::collections::HashMap::new()\n\
                    }\n";
    let (mut findings, _) = lint_source("tuner/injected.rs", injected);
    assert!(!findings.is_empty(), "injected source must violate unordered-map");
    report.findings.append(&mut findings);
    report.findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });

    let src = fs::read_to_string(repo_path("rust/tests/fixtures/lint/baseline.json"))
        .expect("read committed baseline");
    let baseline = Baseline::parse(&src).expect("parse committed baseline");
    let diff = baseline.diff(&report);
    assert!(!diff.clean(), "gate must fail on the injected violation");
    assert!(diff.new.iter().all(|f| f.file == "tuner/injected.rs"));
    assert_eq!(rule_counts(&diff.new)["unordered-map"], 2);

    // and the machine-readable report CI parses says the same
    let json = report::to_json(&report, Some(&diff));
    let new_len = json.get("new").and_then(|v| v.as_arr()).map(|a| a.len());
    assert_eq!(new_len, Some(2));
    let summary_new = json
        .get("summary")
        .and_then(|s| s.get("new"))
        .and_then(|v| v.as_f64());
    assert_eq!(summary_new, Some(2.0));
}

#[test]
fn update_baseline_flow_round_trips_to_a_clean_diff() {
    let report = lint_tree(&repo_path("rust/tests/fixtures/lint/tree")).expect("lint fixtures");
    let baseline = Baseline::from_findings(&report.findings, None);
    let diff = baseline.diff(&report);
    assert!(diff.clean());
    assert_eq!(diff.baselined, report.findings.len());
    assert!(diff.stale.is_empty());
}
