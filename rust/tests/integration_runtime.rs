//! End-to-end AOT bridge tests: load the HLO-text artifacts produced by
//! `make artifacts`, execute them through PJRT, and cross-check the
//! numerics against the independent rust what-if implementation.
//!
//! Tests skip (with a loud message) when artifacts are missing so
//! `cargo test` works before `make artifacts`; `make test` always builds
//! artifacts first.

// SKIP notices print to stderr so they are visible under `cargo test -q`
#![allow(clippy::print_stderr)]

use hadoop_spsa::baselines::CostEvaluator;
use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::{HadoopVersion, ParameterSpace};
use hadoop_spsa::runtime::{ArtifactSpsaStep, ArtifactWhatIf, Runtime, ARTIFACT_K};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::whatif::{cost_for_theta, ClusterFeatures};
use hadoop_spsa::workloads::Benchmark;

fn runtime_or_skip() -> Option<Runtime> {
    if !Runtime::artifacts_present("artifacts") {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return None;
    }
    Some(Runtime::default_dir().expect("PJRT CPU client"))
}

#[test]
fn artifact_matches_rust_whatif() {
    let Some(rt) = runtime_or_skip() else { return };
    let space = ParameterSpace::v1();
    let cluster = ClusterFeatures::from_spec(&ClusterSpec::paper_cluster(), HadoopVersion::V1);
    let mut rng = Rng::seeded(3);
    let w = Benchmark::Terasort.profile_scaled(100_000, 8 << 30, &mut rng);

    let mut artifact = ArtifactWhatIf::new(&rt, space.clone(), &w, &cluster).unwrap();
    let thetas: Vec<Vec<f64>> = (0..300)
        .map(|_| (0..space.dim()).map(|_| rng.f64()).collect())
        .collect();
    let from_artifact = artifact.eval_batch(&thetas);
    for (theta, a) in thetas.iter().zip(&from_artifact) {
        let r = cost_for_theta(&space, theta, &w, &cluster);
        let rel = (a - r).abs() / r.max(1.0);
        assert!(
            rel < 5e-3,
            "artifact {a} vs rust {r} (rel {rel:.2e}) at theta {theta:?}"
        );
    }
    assert_eq!(artifact.model_evals(), 300);
}

#[test]
fn artifact_matches_rust_whatif_v2() {
    let Some(rt) = runtime_or_skip() else { return };
    let space = ParameterSpace::v2();
    let cluster = ClusterFeatures::from_spec(&ClusterSpec::paper_cluster(), HadoopVersion::V2);
    let mut rng = Rng::seeded(5);
    let w = Benchmark::Bigram.profile_scaled(100_000, 1 << 30, &mut rng);

    let mut artifact = ArtifactWhatIf::new(&rt, space.clone(), &w, &cluster).unwrap();
    let thetas: Vec<Vec<f64>> = (0..100)
        .map(|_| (0..space.dim()).map(|_| rng.f64()).collect())
        .collect();
    let got = artifact.eval_batch(&thetas);
    for (theta, a) in thetas.iter().zip(&got) {
        let r = cost_for_theta(&space, theta, &w, &cluster);
        let rel = (a - r).abs() / r.max(1.0);
        assert!(rel < 5e-3, "artifact {a} vs rust {r} at theta {theta:?}");
    }
}

#[test]
fn spsa_step_artifact_descends_model() {
    let Some(rt) = runtime_or_skip() else { return };
    let space = ParameterSpace::v1();
    let cluster = ClusterFeatures::from_spec(&ClusterSpec::paper_cluster(), HadoopVersion::V1);
    let mut rng = Rng::seeded(7);
    let w = Benchmark::Terasort.profile_scaled(100_000, 8 << 30, &mut rng);

    let stepper = ArtifactSpsaStep::new(&rt, &space, &w, &cluster).unwrap();
    let c_scales: Vec<f64> = space
        .params()
        .iter()
        .map(|p| (1.0 / p.width().max(1e-9)).clamp(0.02, 0.25))
        .collect();

    let mut theta = space.default_theta();
    let mut first = None;
    let mut last = 0.0;
    for iter in 0..40 {
        let signs: Vec<Vec<f64>> = (0..ARTIFACT_K)
            .map(|_| (0..space.dim()).map(|_| rng.rademacher()).collect())
            .collect();
        let out = stepper.step(&theta, &signs, &c_scales, 0.01, 0.15).unwrap();
        assert!(out.theta_next.iter().all(|t| (0.0..=1.0).contains(t)));
        assert_eq!(out.ghat.len(), space.dim());
        theta = out.theta_next;
        if iter == 0 {
            first = Some(out.f_theta);
        }
        last = out.f_theta;
    }
    let first = first.unwrap();
    assert!(
        last < 0.6 * first,
        "surrogate SPSA did not descend: {first} -> {last}"
    );
}

#[test]
fn rrs_over_artifact_beats_default_on_simulator() {
    // The full Starfish pipeline with the artifact as what-if engine.
    let Some(rt) = runtime_or_skip() else { return };
    use hadoop_spsa::baselines::{rrs, RrsConfig};
    use hadoop_spsa::sim::{simulate, SimOptions};

    let space = ParameterSpace::v1();
    let cluster_spec = ClusterSpec::paper_cluster();
    let cluster = ClusterFeatures::from_spec(&cluster_spec, HadoopVersion::V1);
    let mut rng = Rng::seeded(11);
    let w = Benchmark::InvertedIndex.profile_scaled(100_000, 4 << 30, &mut rng);

    let mut artifact = ArtifactWhatIf::new(&rt, space.clone(), &w, &cluster).unwrap();
    let res = rrs(&mut artifact, &RrsConfig { budget: 1500, ..Default::default() });

    let opts = SimOptions { seed: 13, noise: false, ..Default::default() };
    let f_default = simulate(&cluster_spec, &space.default_config(), &w, &opts).exec_time_s;
    let f_tuned = simulate(&cluster_spec, &space.materialize(&res.best_theta), &w, &opts).exec_time_s;
    assert!(
        f_tuned < 0.8 * f_default,
        "artifact-RRS config not better: {f_tuned} vs {f_default}"
    );
}
